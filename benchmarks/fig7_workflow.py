"""Paper Fig. 7 / §4.3: the digital content-creation workflow end to end,
greedy vs partitioning (+ SLO-aware)."""
from __future__ import annotations

from benchmarks.common import row
from repro.core.orchestrator import Orchestrator
from repro.core.workflow import CONTENT_CREATION_YAML, parse_workflow


def run() -> list[str]:
    rows = []
    wf = parse_workflow(CONTENT_CREATION_YAML)
    e2e = {}
    for strategy in ("greedy", "static", "slo_aware"):
        orch = Orchestrator(total_chips=256, strategy=strategy)
        res = orch.run_workflow(wf)
        e2e[strategy] = res.e2e_s
        cap = res.sim.reports["generate_captions"]
        img = res.sim.reports["cover_art"]
        rows.append(row(
            f"fig7_workflow_{strategy}",
            res.e2e_s * 1e6,
            f"captions_slo={cap.attainment:.3f};"
            f"imagegen_slo={img.attainment:.3f};"
            f"util={res.sim.utilization():.3f};"
            f"energy_kj={res.sim.energy_j() / 1e3:.1f}"))
    speedup = (e2e["static"] - e2e["greedy"]) / e2e["static"]
    rows.append(row("fig7_greedy_vs_static_e2e_saving", speedup * 1e6,
                    f"paper_claims=0.45;measured={speedup:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
