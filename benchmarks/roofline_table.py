"""The §Roofline table: per (arch × shape × mesh) terms from the dry-run
results (results/dryrun_all.jsonl). Emits one CSV row per cell; also
renders the markdown table EXPERIMENTS.md embeds."""
from __future__ import annotations

import json
import os

from benchmarks.common import row

RESULTS = os.environ.get("REPRO_DRYRUN_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "results", "dryrun_all.jsonl"))


def load() -> list[dict]:
    if not os.path.exists(RESULTS):
        return []
    out = []
    with open(RESULTS) as f:
        for line in f:
            try:
                out.append(json.loads(line))
            except Exception:
                pass
    return out


def run() -> list[str]:
    rows = []
    for d in load():
        tag = f"roofline_{d['arch']}_{d['shape']}_{'multi' if 'pod' in d.get('mesh', '') else 'single'}"
        if d.get("status") == "ok":
            rows.append(row(tag, d["step_time_s"] * 1e6,
                            f"dominant={d['dominant']};"
                            f"compute_s={d['compute_s']:.4g};"
                            f"memory_s={d['memory_s']:.4g};"
                            f"collective_s={d['collective_s']:.4g};"
                            f"mfu={d['roofline_fraction']:.4f};"
                            f"useful={d['useful_flops_ratio']:.3f}"))
        elif d.get("status") == "skipped":
            rows.append(row(tag, 0.0, "skipped=" + d.get("reason", "")[:40]))
    if not rows:
        rows.append(row("roofline_table_missing", 0.0,
                        f"run launch.dryrun --all first ({RESULTS})"))
    return rows


def markdown_table() -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | step s | MFU | useful |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for d in load():
        mesh = "multi" if "pod" in d.get("mesh", "") else "single"
        if d.get("status") == "ok":
            lines.append(
                f"| {d['arch']} | {d['shape']} | {mesh} "
                f"| {d['compute_s']:.4f} | {d['memory_s']:.4f} "
                f"| {d['collective_s']:.4f} | **{d['dominant']}** "
                f"| {d['step_time_s']:.4f} | {d['roofline_fraction']:.3f} "
                f"| {d['useful_flops_ratio']:.2f} |")
        elif d.get("status") == "skipped":
            lines.append(f"| {d['arch']} | {d['shape']} | {mesh} "
                         f"| — | — | — | skipped | — | — | — |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
