"""Result-schema consumer: render ``ScenarioResult.to_json()`` documents to
a markdown report and (optionally) PNG charts — the non-diff half of the
dashboard (``diff_results.py`` is the regression-diff half).

Input: any mix of files, each holding one document or a JSON array of
documents (e.g. a ``Scenario.sweep()`` saved as a list). Works on schema
1.0–1.8; the 1.2 ``memory`` block (page utilization, evictions, recompute),
the 1.3 ``telemetry`` block (utilization/bandwidth timelines, Gantt
spans), the 1.4 ``prefix`` block (radix-cache hit rate, shared pages,
CoW forks), the 1.6 ``routing`` block (per-replica load, imbalance,
affinity hits), the 1.7 ``batching`` block (mixed steps, decode-stall
fraction, plus per-app TPOT p99) and the 1.8 ``attribution`` block
(goodput under SLO, per-app critical-path blame shares) are surfaced when
present — a telemetry-enabled document
renders a per-app Gantt chart plus SMACT/SMOCC and bandwidth timelines,
prefix-enabled documents add a hit-rate-vs-shared-fraction curve (shared
fraction read off each document's conversation spec), router-enabled
documents add per-replica routed-token bars plus, across documents that
sweep ``replicas``, an attainment-vs-replicas curve, and
attribution-enabled documents add a stacked per-app blame-table bar chart
(where each app's latency went: queue/sched/prefill/decode/recompute/
stall/fault).

    python benchmarks/plot_results.py results/*.json            # markdown
    python benchmarks/plot_results.py sweep.json --png out.png  # + charts

The PNG needs matplotlib; without it the command still emits the markdown
report and says what it skipped. Charts follow the repo's dataviz rules:
fixed-order categorical palette (never cycled), one axis per chart, thin
marks, direct labels, a legend whenever more than one series is shown —
and the markdown table IS the accessible table view of the same data.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Optional

# fixed-order categorical palette (validated; assign by slot, never cycle —
# >4 series fold into "other")
SERIES = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100")
TEXT_PRIMARY = "#0b0b0b"
TEXT_SECONDARY = "#52514e"
GRID = "#e3e2de"
SURFACE = "#fcfcfb"
MAX_SERIES = 4


# ----------------------------------------------------------------- loading
def load_docs(paths: list[str]) -> list[dict]:
    docs: list[dict] = []
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        docs.extend(doc if isinstance(doc, list) else [doc])
    bad = [d for d in docs if "schema_version" not in d or "results" not in d]
    if bad:
        raise ValueError(
            "not a ScenarioResult to_json() document (missing "
            "schema_version/results); got keys "
            f"{sorted(bad[0])[:6]} — BENCH_*.json kernel documents go to "
            "diff_results.py, not here")
    return docs


def _arrival_rate(doc: dict) -> Optional[float]:
    """The swept Poisson rate, when every app shares one (sweep points)."""
    rates = set()
    for app in doc.get("scenario", {}).get("apps", []):
        arr = app.get("arrival") or {}
        if arr.get("kind") == "poisson":
            rates.add(float(arr["rate_per_s"]))
    return rates.pop() if len(rates) == 1 else None


def flatten(doc: dict) -> list[dict]:
    """One row per (sim label, app) with the metrics the report shows."""
    rows = []
    scenario = doc.get("scenario", {})
    name = scenario.get("name", "scenario")
    substrate = doc.get("substrate", scenario.get("substrate", "simulator"))
    rate = _arrival_rate(doc)
    for label, summary in doc.get("results", {}).items():
        if not isinstance(summary, dict) or "apps" not in summary:
            continue
        mem = summary.get("memory", {})
        tel = summary.get("telemetry", {})
        pfx = summary.get("prefix", {})
        rt = summary.get("routing", {})
        routed = rt if rt.get("enabled") else {}
        bt = summary.get("batching", {})
        batched = bt if bt.get("enabled") else {}
        at = summary.get("attribution", {})
        attrib = at if at.get("enabled") else {}
        for app, stats in summary["apps"].items():
            shares = attrib.get("per_app", {}).get(app, {}).get("shares", {})
            rows.append({
                "scenario": name, "substrate": substrate, "label": label,
                "app": app, "rate_per_s": rate,
                "attainment": stats.get("slo_attainment"),
                "p99_s": stats.get("p99"),
                "tpot_p99_s": stats.get("tpot_p99"),
                "makespan_s": summary.get("makespan_s"),
                "page_utilization": mem.get("page_utilization"),
                "evictions": mem.get("evictions"),
                "recompute_tokens": mem.get("recompute_tokens"),
                "smact_mean": tel.get("smact_mean"),
                "smocc_mean": tel.get("smocc_mean"),
                "bandwidth_gbs_mean": tel.get("bandwidth_gbs_mean"),
                "prefix_hit_rate": pfx.get("hit_rate"),
                "shared_pages": pfx.get("shared_pages"),
                "cow_forks": pfx.get("cow_forks"),
                "routing_policy": routed.get("policy"),
                "replicas": routed.get("replicas"),
                "imbalance": routed.get("imbalance"),
                "affinity_hits": routed.get("affinity_hits"),
                "mixed_steps": batched.get("mixed_steps"),
                "stall_fraction": batched.get("decode_stall_fraction"),
                "goodput_rps": attrib.get("goodput_rps"),
                "queue_share": shares.get("queue"),
                "stall_share": shares.get("stall"),
                "fault_share": shares.get("fault"),
            })
    return rows


def telemetry_blocks(docs: list[dict]) -> list[tuple[str, str, dict]]:
    """Every (scenario, label, telemetry block) across the documents."""
    out = []
    for doc in docs:
        name = doc.get("scenario", {}).get("name", "scenario")
        for label, summary in doc.get("results", {}).items():
            if isinstance(summary, dict) and "telemetry" in summary:
                out.append((name, label, summary["telemetry"]))
    return out


def routing_blocks(docs: list[dict]) -> list[tuple[str, str, dict]]:
    """Every (scenario, label, routing block) with a live router."""
    out = []
    for doc in docs:
        name = doc.get("scenario", {}).get("name", "scenario")
        for label, summary in doc.get("results", {}).items():
            rt = (summary.get("routing")
                  if isinstance(summary, dict) else None)
            if rt and rt.get("enabled"):
                out.append((name, label, rt))
    return out


#: schema-1.8 critical-path buckets, canonical order (matches
#: repro.telemetry.requests.BUCKETS; kept literal — this tool is stdlib-only)
BLAME_BUCKETS = ("queue", "sched", "prefill", "decode", "recompute",
                 "stall", "fault")


def attribution_blocks(docs: list[dict]) -> list[tuple[str, str, dict]]:
    """Every (scenario, label, attribution block) with a live pipeline."""
    out = []
    for doc in docs:
        name = doc.get("scenario", {}).get("name", "scenario")
        for label, summary in doc.get("results", {}).items():
            at = (summary.get("attribution")
                  if isinstance(summary, dict) else None)
            if at and at.get("enabled") and at.get("per_app"):
                out.append((name, label, at))
    return out


def replica_points(docs: list[dict]) -> list[tuple[int, float, str]]:
    """(replica count, mean attainment, scenario name) per router-enabled
    result — the replica-scaling curve across a ``sweep_replicas`` run."""
    pts = []
    for doc in docs:
        name = doc.get("scenario", {}).get("name", "scenario")
        for _label, summary in doc.get("results", {}).items():
            if not isinstance(summary, dict) or "apps" not in summary:
                continue
            rt = summary.get("routing") or {}
            apps = summary["apps"]
            if not rt.get("enabled") or not apps:
                continue
            att = (sum(a["slo_attainment"] for a in apps.values())
                   / len(apps))
            pts.append((int(rt.get("replicas", 1)), att, name))
    return pts


def _shared_frac(doc: dict) -> Optional[float]:
    """System-prompt share of the final-turn context, read off the
    scenario's conversation spec (None without a conversation app)."""
    for app in doc.get("scenario", {}).get("apps", []):
        conv = app.get("conversation") or {}
        if conv:
            sys_t = conv.get("system_tokens", 0)
            turns = conv.get("turns", 1)
            foot = sys_t + turns * (conv.get("user_tokens", 0)
                                    + conv.get("assistant_tokens", 0))
            return sys_t / foot if foot else None
    return None


def prefix_points(docs: list[dict]) -> list[tuple[float, float, str]]:
    """(shared fraction, hit rate, scenario name) per prefix-enabled
    result; documents without a conversation spec use their load order
    as the x position so the curve still renders."""
    pts = []
    for i, doc in enumerate(docs):
        frac = _shared_frac(doc)
        name = doc.get("scenario", {}).get("name", "scenario")
        for _label, summary in doc.get("results", {}).items():
            pfx = (summary.get("prefix")
                   if isinstance(summary, dict) else None)
            if pfx and pfx.get("enabled"):
                pts.append((float(i) if frac is None else frac,
                            pfx["hit_rate"], name))
    return pts


# ---------------------------------------------------------------- markdown
def _fmt(v: Any) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def to_markdown(rows: list[dict]) -> str:
    cols = ["scenario", "substrate", "app", "rate_per_s", "attainment",
            "p99_s", "tpot_p99_s", "page_utilization", "evictions",
            "recompute_tokens",
            "smact_mean", "smocc_mean", "bandwidth_gbs_mean",
            "prefix_hit_rate", "shared_pages", "cow_forks",
            "routing_policy", "replicas", "imbalance", "affinity_hits",
            "mixed_steps", "stall_fraction",
            "goodput_rps", "queue_share", "stall_share", "fault_share"]
    # drop all-empty optional columns (memory block absent on <1.2 docs)
    cols = [c for c in cols
            if c in ("scenario", "substrate", "app")
            or any(r.get(c) is not None for r in rows)]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(_fmt(r.get(c)) for c in cols) + " |")
    return "\n".join(out)


# ------------------------------------------------------------------- plots
def render_png(rows: list[dict], path: str,
               docs: Optional[list] = None) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("# matplotlib unavailable: skipped PNG (markdown above is "
              "the full report)", file=sys.stderr)
        return False

    sweep = [r for r in rows if r["rate_per_s"] is not None
             and r["attainment"] is not None]
    mem = {}
    for r in rows:
        if r["evictions"] is not None:
            mem.setdefault((r["scenario"], r["label"]), r)
    tel = telemetry_blocks(docs or [])
    if len(tel) > 1:
        print(f"# rendering first of {len(tel)} telemetry blocks "
              f"({tel[0][0]}/{tel[0][1]})", file=sys.stderr)
    pfx_pts = prefix_points(docs or [])
    rt_blocks = routing_blocks(docs or [])
    if len(rt_blocks) > 1:
        print(f"# rendering first of {len(rt_blocks)} routing blocks "
              f"({rt_blocks[0][0]}/{rt_blocks[0][1]})", file=sys.stderr)
    rep_pts = replica_points(docs or [])
    # the scaling curve needs at least two distinct replica counts
    if len({p[0] for p in rep_pts}) < 2:
        rep_pts = []
    at_blocks = attribution_blocks(docs or [])
    if len(at_blocks) > 1:
        print(f"# rendering first of {len(at_blocks)} attribution blocks "
              f"({at_blocks[0][0]}/{at_blocks[0][1]})", file=sys.stderr)
    panels = ((1 if sweep else 0) + (2 if mem else 0) + (3 if tel else 0)
              + (1 if pfx_pts else 0) + (1 if rt_blocks else 0)
              + (1 if rep_pts else 0) + (1 if at_blocks else 0))
    if not panels:
        print("# nothing to plot: no sweep points, memory blocks or "
              "telemetry blocks", file=sys.stderr)
        return False

    fig, axes = plt.subplots(1, panels, figsize=(5.2 * panels, 3.6))
    axes = [axes] if panels == 1 else list(axes)
    for ax in axes:
        ax.set_facecolor(SURFACE)
        ax.grid(True, color=GRID, linewidth=0.8)
        ax.set_axisbelow(True)
        for spine in ("top", "right"):
            ax.spines[spine].set_visible(False)
        for spine in ("left", "bottom"):
            ax.spines[spine].set_color(GRID)
        ax.tick_params(colors=TEXT_SECONDARY, labelsize=8)
    fig.patch.set_facecolor(SURFACE)

    if sweep:
        ax = axes.pop(0)
        apps = []
        for r in sweep:                       # fixed first-seen slot order
            if r["app"] not in apps:
                apps.append(r["app"])
        shown, folded = apps[:MAX_SERIES], apps[MAX_SERIES:]
        for slot, app in enumerate(shown):
            pts = sorted((r["rate_per_s"], r["attainment"])
                         for r in sweep if r["app"] == app)
            xs, ys = zip(*pts)
            ax.plot(xs, ys, color=SERIES[slot], linewidth=2,
                    marker="o", markersize=4, label=app)
            # stagger end labels per slot: coincident series (e.g. every
            # app at attainment 1.0) must not overprint
            ax.annotate(app, (xs[-1], ys[-1]), textcoords="offset points",
                        xytext=(6, -slot * 11), fontsize=8,
                        color=TEXT_PRIMARY)
        if folded:
            print(f"# folded {len(folded)} app(s) beyond {MAX_SERIES} "
                  f"series: {', '.join(folded)}", file=sys.stderr)
        ax.set_xlabel("arrival rate (req/s)", color=TEXT_SECONDARY,
                      fontsize=9)
        ax.set_ylabel("SLO attainment", color=TEXT_SECONDARY, fontsize=9)
        ax.set_ylim(-0.02, 1.05)
        if len(shown) > 1:
            ax.legend(fontsize=8, frameon=False, labelcolor=TEXT_PRIMARY)
        ax.set_title("attainment vs Poisson rate", color=TEXT_PRIMARY,
                     fontsize=10)

    if tel:
        name, label, blk = tel[0]
        dt = blk.get("dt_s", 0.0) or 1.0
        ts = [(b + 0.5) * dt for b in range(len(blk["smact"]))]
        # utilization timeline: SMACT + roofline-achieved SMOCC
        ax = axes.pop(0)
        ax.plot(ts, blk["smact"], color=SERIES[0], linewidth=1.5,
                label="SMACT")
        ax.plot(ts, blk["smocc"], color=SERIES[1], linewidth=1.5,
                label="SMOCC")
        ax.set_ylim(-0.02, 1.05)
        ax.set_xlabel("time (s)", color=TEXT_SECONDARY, fontsize=9)
        ax.set_ylabel("fraction of pod", color=TEXT_SECONDARY, fontsize=9)
        ax.legend(fontsize=8, frameon=False, labelcolor=TEXT_PRIMARY)
        ax.set_title(f"utilization — {name}/{label}", color=TEXT_PRIMARY,
                     fontsize=10)
        # memory-bandwidth timeline (its own axis: different unit)
        ax = axes.pop(0)
        ax.plot(ts, blk["bandwidth_gbs"], color=SERIES[2], linewidth=1.5)
        ax.set_xlabel("time (s)", color=TEXT_SECONDARY, fontsize=9)
        ax.set_ylabel("HBM GB/s", color=TEXT_SECONDARY, fontsize=9)
        ax.set_title("memory bandwidth", color=TEXT_PRIMARY, fontsize=10)
        # per-app Gantt: one lane per app, spans colored by slot order
        ax = axes.pop(0)
        apps = list(blk.get("spans", {}))
        for lane, app in enumerate(apps):
            color = SERIES[lane % MAX_SERIES]
            for t0, t1, _kind in blk["spans"][app]:
                ax.barh(lane, max(t1 - t0, dt / 4), left=t0, height=0.6,
                        color=color, edgecolor="none")
        ax.set_yticks(range(len(apps)))
        ax.set_yticklabels(apps, fontsize=8, color=TEXT_SECONDARY)
        ax.invert_yaxis()
        ax.set_xlabel("time (s)", color=TEXT_SECONDARY, fontsize=9)
        ax.set_title("per-app Gantt", color=TEXT_PRIMARY, fontsize=10)

    if pfx_pts:
        # shared-fraction curve: hit rate rises, residual prefill falls
        ax = axes.pop(0)
        pts = sorted(pfx_pts)
        xs = [p[0] for p in pts]
        hits = [p[1] for p in pts]
        ax.plot(xs, hits, color=SERIES[0], linewidth=2, marker="o",
                markersize=4, label="hit rate")
        ax.plot(xs, [1.0 - h for h in hits], color=SERIES[1], linewidth=2,
                marker="o", markersize=4, label="prefill fraction")
        ax.set_ylim(-0.02, 1.05)
        ax.set_xlabel("shared prefix fraction", color=TEXT_SECONDARY,
                      fontsize=9)
        ax.set_ylabel("fraction of prompt tokens", color=TEXT_SECONDARY,
                      fontsize=9)
        ax.legend(fontsize=8, frameon=False, labelcolor=TEXT_PRIMARY)
        ax.set_title("prefix cache vs shared fraction", color=TEXT_PRIMARY,
                     fontsize=10)

    if rt_blocks:
        # per-replica routed-token bars: the load-distribution fingerprint
        # of one routing policy (imbalance annotated in the title)
        ax = axes.pop(0)
        name, label, blk = rt_blocks[0]
        loads = blk.get("per_replica_load", {})
        reps = list(loads)
        vals = [loads[r] for r in reps]
        ax.bar(range(len(vals)), vals, color=SERIES[0], width=0.62)
        ax.set_xticks(range(len(vals)))
        ax.set_xticklabels([r.rsplit("#", 1)[-1] for r in reps],
                           fontsize=8, color=TEXT_SECONDARY)
        for i, v in enumerate(vals):
            ax.annotate(_fmt(v), (i, v), ha="center",
                        textcoords="offset points", xytext=(0, 3),
                        fontsize=8, color=TEXT_PRIMARY)
        ax.set_ylabel("routed tokens", color=TEXT_SECONDARY, fontsize=9)
        ax.set_title(f"replica load — {blk.get('policy', '?')} "
                     f"(imbalance {_fmt(blk.get('imbalance'))})",
                     color=TEXT_PRIMARY, fontsize=10)

    if rep_pts:
        # replica-scaling curve: mean attainment as the fleet grows
        ax = axes.pop(0)
        by_rep: dict[int, list[float]] = {}
        for n, att, _name in rep_pts:
            by_rep.setdefault(n, []).append(att)
        xs = sorted(by_rep)
        ys = [sum(by_rep[x]) / len(by_rep[x]) for x in xs]
        ax.plot(xs, ys, color=SERIES[1], linewidth=2, marker="o",
                markersize=4)
        ax.set_xticks(xs)
        ax.set_ylim(-0.02, 1.05)
        ax.set_xlabel("replicas", color=TEXT_SECONDARY, fontsize=9)
        ax.set_ylabel("mean SLO attainment", color=TEXT_SECONDARY,
                      fontsize=9)
        ax.set_title("attainment vs replicas", color=TEXT_PRIMARY,
                     fontsize=10)

    if at_blocks:
        # blame-table bars: one stacked bar per app, segments ordered by
        # the canonical bucket order; zero-share buckets vanish naturally
        ax = axes.pop(0)
        name, label, blk = at_blocks[0]
        apps = list(blk["per_app"])
        bottoms = [0.0] * len(apps)
        for slot, bucket in enumerate(BLAME_BUCKETS):
            vals = [blk["per_app"][a].get("shares", {}).get(bucket, 0.0)
                    for a in apps]
            if not any(vals):
                continue
            ax.bar(range(len(apps)), vals, bottom=bottoms,
                   color=SERIES[slot % MAX_SERIES], width=0.62,
                   label=bucket)
            bottoms = [b + v for b, v in zip(bottoms, vals)]
        ax.set_xticks(range(len(apps)))
        ax.set_xticklabels(apps, fontsize=8, color=TEXT_SECONDARY)
        ax.set_ylim(0, 1.05)
        ax.set_ylabel("share of e2e latency", color=TEXT_SECONDARY,
                      fontsize=9)
        ax.legend(fontsize=8, frameon=False, labelcolor=TEXT_PRIMARY)
        ax.set_title(f"critical-path blame — {name}/{label} "
                     f"(goodput {_fmt(blk.get('goodput_rps'))}/s)",
                     color=TEXT_PRIMARY, fontsize=10)

    if mem:
        labels = [f"{s}\n{l}" if l != "concurrent" else s
                  for s, l in mem]
        # two measures of different scale -> two charts, never a dual axis
        for ax, key, title in ((axes[0], "page_utilization",
                                "peak page utilization"),
                               (axes[1], "evictions", "evictions")):
            vals = [m[key] or 0 for m in mem.values()]
            ax.bar(range(len(vals)), vals, color=SERIES[0], width=0.62)
            ax.set_xticks(range(len(vals)))
            ax.set_xticklabels(labels, fontsize=7, color=TEXT_SECONDARY)
            for i, v in enumerate(vals):
                ax.annotate(_fmt(v), (i, v), ha="center",
                            textcoords="offset points", xytext=(0, 3),
                            fontsize=8, color=TEXT_PRIMARY)
            ax.set_title(title, color=TEXT_PRIMARY, fontsize=10)

    fig.tight_layout()
    fig.savefig(path, dpi=144)
    print(f"# wrote {path}", file=sys.stderr)
    return True


# -------------------------------------------------------------------- main
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("paths", nargs="+",
                    help="ScenarioResult to_json() files (or JSON arrays "
                         "of them, e.g. a saved sweep)")
    ap.add_argument("--png", default="",
                    help="also render charts to this PNG (needs matplotlib)")
    args = ap.parse_args(argv)

    docs = load_docs(args.paths)
    rows = [r for doc in docs for r in flatten(doc)]
    if not rows:
        print("no app results found", file=sys.stderr)
        return 1
    print(to_markdown(rows))
    if args.png:
        render_png(rows, args.png, docs)
    return 0


if __name__ == "__main__":
    sys.exit(main())
