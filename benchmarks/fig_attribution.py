"""Critical-path attribution under contention: where does each policy's
request latency GO, and what goodput survives the SLO gate?

Runs the fig5 contention family (the paper's three-app concurrent
workload, one run per scheduling policy) with streaming telemetry
enabled, and reports the schema-1.8 ``attribution`` block per policy:

* **goodput_rps** — SLO-meeting completions per second of makespan (the
  goodput-under-SLO curve across policies; higher is better),
* **blame shares** — the per-app critical-path seconds (queue / sched /
  prefill / decode / recompute / stall / fault — they partition each
  request's wall-clock latency exactly) aggregated into one blame table
  per run; queue/stall/fault shares are the "wasted" latency a better
  policy should shrink (lower is better in bench-diff).

Engine rows re-run a subset of policies on the real InferenceEngine and
report ``parity_gap``: the largest absolute difference between the two
substrates' WORK-side blame composition (prefill/decode/recompute as a
share of total work seconds, plus the fault share of e2e) — the
attribution the shared virtual cost model guarantees to match, and the
pipeline's cross-substrate acceptance metric (≤ 0.05; in practice ~0
because both substrates charge identical per-token costs). The wait-side
buckets (queue/sched/stall) are reported per-substrate but NOT parity
gated: they attribute genuinely different scheduling — the engine
time-slices requests through continuous-batching slots (admitted work
waits for its prefill turn → ``sched``), while the analytic simulator
runs dispatch chunks to completion (the same waiting shows up queued
between chunks → ``stall``) — so their per-request latency mixes differ
by design, exactly the behavior the blame table exists to expose.

All rows are virtual-clock deterministic and diff in CI
(``BENCH_attribution.json``).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import smoke_enabled, standard_scenario, row
from repro.telemetry.requests import BUCKETS

#: the fig5 policy family (keep in sync with fig5_concurrent.POLICIES)
POLICIES = ("greedy", "static", "slo_aware", "weighted_fair",
            "preemptive_priority")
POLICIES_SMOKE = ("greedy", "slo_aware")
#: policies re-run on the engine substrate for the parity rows
ENGINE_POLICIES = ("greedy", "slo_aware")
ENGINE_POLICIES_SMOKE = ("slo_aware",)


def scenario(policy: str, substrate: str = "simulator"):
    sc = standard_scenario(f"attribution-{policy}", policy,
                           substrate=substrate)
    return dataclasses.replace(sc, telemetry=True)


#: buckets whose seconds come from the shared cost model (parity-gated)
WORK_BUCKETS = ("prefill", "decode", "recompute")


def _agg_shares(at: dict) -> dict:
    """One blame table for the whole run: per-app seconds summed, then
    normalized — the bars of the attribution figure."""
    total = sum(t["e2e_total_s"] for t in at["per_app"].values())
    if total <= 0:
        return {b: 0.0 for b in BUCKETS}
    return {b: sum(t["seconds"][b] for t in at["per_app"].values()) / total
            for b in BUCKETS}


def work_composition(at: dict) -> dict:
    """Parity-gated attribution: prefill/decode/recompute as shares of
    total WORK seconds, plus the fault share of e2e. These are pinned to
    the shared cost model, so the substrates must agree to <= 0.05."""
    secs = {b: sum(t["seconds"][b] for t in at["per_app"].values())
            for b in BUCKETS}
    work = sum(secs[b] for b in WORK_BUCKETS)
    e2e = sum(t["e2e_total_s"] for t in at["per_app"].values())
    out = {b: (secs[b] / work if work > 0 else 0.0) for b in WORK_BUCKETS}
    out["fault"] = secs["fault"] / e2e if e2e > 0 else 0.0
    return out


def _derived(at: dict, shares: dict, extra: str = "") -> str:
    s = (f"goodput_rps={at['goodput_rps']:.4f};"
         f"slo_ok={at['slo_ok']};"
         f"requests={at['requests']};"
         + ";".join(f"{b}_share={shares[b]:.4f}" for b in BUCKETS))
    return s + (";" + extra if extra else "")


def run() -> list[str]:
    smoke = smoke_enabled()
    policies = POLICIES_SMOKE if smoke else POLICIES
    eng_policies = ENGINE_POLICIES_SMOKE if smoke else ENGINE_POLICIES
    rows = []
    sim_comp: dict[str, dict] = {}
    for policy in policies:
        s = scenario(policy).run().sim.summary()
        at = s["attribution"]
        sim_comp[policy] = work_composition(at)
        rows.append(row(f"attribution_sim_{policy}",
                        s["makespan_s"] * 1e6,
                        _derived(at, _agg_shares(at))))
    for policy in eng_policies:
        s = scenario(policy, substrate="engine").run().sim.summary()
        at = s["attribution"]
        comp = work_composition(at)
        gap = (max(abs(comp[k] - sim_comp[policy][k]) for k in comp)
               if policy in sim_comp else 0.0)
        rows.append(row(f"attribution_engine_{policy}",
                        s["makespan_s"] * 1e6,
                        _derived(at, _agg_shares(at),
                                 f"parity_gap={gap:.4f}")))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
