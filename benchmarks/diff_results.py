"""CI regression dashboard: diff two versioned result documents.

Accepts either document family this repo emits:

* **Scenario documents** — ``ScenarioResult.to_json()`` (``schema_version``
  1.0–1.8): per-app SLO attainment, latency percentiles (p50/p99/mean,
  plus the 1.7 ttft/tpot/itl token-latency percentiles),
  makespan/utilization, workflow ``e2e_s``, the 1.2 ``memory`` block, the
  1.3 ``telemetry`` scalars (mean SMACT/SMOCC/bandwidth/power, KV peak),
  the 1.6 ``routing`` scalars (routed/affinity_hits/imbalance, when a
  router is enabled), the 1.7 ``batching`` scalars (mixed_steps and
  decode_stall_fraction, when a step-budget policy ran — stall fraction
  diffs lower-is-better), and the 1.8 ``attribution`` scalars
  (goodput_rps higher-is-better; the stall/fault blame shares regress
  when they RISE, like every lower-is-better metric). A file may also
  hold a JSON list of such documents (e.g. one per policy).
* **BENCH documents** — ``benchmarks/run.py --json`` (``version`` 1):
  ``us_per_call`` per suite/row, which covers both timings and dispatch
  counters (``engine_dispatch_*`` rows).

Exit status: 0 = no regressions (or baseline missing with ``--missing-ok``),
1 = at least one metric regressed beyond ``--threshold`` (default 10%),
2 = usage/parse error. Higher-is-better metrics (attainment, utilization)
regress when they DROP by more than the threshold; everything else
(latencies, makespan, energy, us_per_call) regresses when it RISES.

    python benchmarks/diff_results.py old.json new.json --markdown

is what the ``bench-diff`` CI job runs, posting the table as a step
summary. Standalone on purpose: stdlib only, no repro/ imports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: metric-name suffixes where HIGHER is better (everything else: lower —
#: notably decode_stall_fraction, which regresses when it RISES)
HIGHER_IS_BETTER = ("slo_attainment", "utilization", "attainment",
                    "smact_mean", "smocc_mean", "affinity_hits",
                    "mixed_steps", "goodput_rps", "slo_ok")
#: ignore absolute deltas below this (in metric units) — keeps near-zero
#: virtual-clock metrics from tripping the relative threshold
DEFAULT_MIN_ABS = 1e-9


# ------------------------------------------------------------- extraction
def _is_bench_doc(doc: dict) -> bool:
    return "entries" in doc and "version" in doc


def _is_scenario_doc(doc: dict) -> bool:
    return "schema_version" in doc and "results" in doc


def _scenario_metrics(doc: dict) -> dict[str, float]:
    """Flatten a ScenarioResult document into {metric_path: value}."""
    name = doc.get("scenario", {}).get("name", "scenario")
    substrate = doc.get("substrate",
                        doc.get("scenario", {}).get("substrate", "simulator"))
    base = f"{name}[{substrate}]"
    out: dict[str, float] = {}
    results = doc.get("results", {})
    for label, summary in results.items():
        if label == "e2e_s":
            out[f"{base}/e2e_s"] = float(summary)
            continue
        if not isinstance(summary, dict) or "apps" not in summary:
            continue
        for key in ("makespan_s", "utilization", "energy_kj"):
            if key in summary:
                out[f"{base}/{label}/{key}"] = float(summary[key])
        for key in ("page_utilization", "evictions", "recompute_tokens"):
            if key in summary.get("memory", {}):   # schema 1.2 memory block
                out[f"{base}/{label}/memory/{key}"] = \
                    float(summary["memory"][key])
        rt = summary.get("routing", {})            # schema 1.6 routing
        if rt.get("enabled"):
            for key in ("routed", "affinity_hits", "imbalance"):
                out[f"{base}/{label}/routing/{key}"] = float(rt.get(key, 0))
        bt = summary.get("batching", {})           # schema 1.7 batching
        if bt.get("enabled"):
            for key in ("mixed_steps", "decode_stall_fraction"):
                out[f"{base}/{label}/batching/{key}"] = float(bt.get(key, 0))
        tel = summary.get("telemetry", {})         # schema 1.3 telemetry
        for key in ("smact_mean", "smocc_mean", "bandwidth_gbs_mean",
                    "power_w_mean", "kv_pages_peak"):
            if key in tel:
                out[f"{base}/{label}/telemetry/{key}"] = float(tel[key])
        at = summary.get("attribution", {})        # schema 1.8 attribution
        if at.get("enabled"):
            out[f"{base}/{label}/attribution/goodput_rps"] = \
                float(at.get("goodput_rps", 0.0))
            out[f"{base}/{label}/attribution/slo_ok"] = \
                float(at.get("slo_ok", 0))
            for app, tbl in at.get("per_app", {}).items():
                for b in ("queue", "stall", "fault"):
                    out[f"{base}/{label}/attribution/{app}/{b}_share"] = \
                        float(tbl.get("shares", {}).get(b, 0.0))
        for app, stats in summary["apps"].items():
            for key in ("slo_attainment", "mean", "p50", "p99",
                        "ttft_p99", "tpot_p99", "itl_p99"):
                if key in stats:
                    out[f"{base}/{label}/{app}/{key}"] = float(stats[key])
    return out


def _bench_metrics(doc: dict) -> dict[str, float]:
    out = {}
    for e in doc.get("entries", []):
        out[f"{e['suite']}/{e['name']}/us_per_call"] = float(e["us_per_call"])
    return out


def extract_metrics(doc) -> dict[str, float]:
    """Document (or list of documents) -> flat {metric_path: value}."""
    if isinstance(doc, list):
        out: dict[str, float] = {}
        for i, d in enumerate(doc):
            sub = extract_metrics(d)
            for k, v in sub.items():
                out[k if k not in out else f"#{i}/{k}"] = v
        return out
    if _is_bench_doc(doc):
        return _bench_metrics(doc)
    if _is_scenario_doc(doc):
        return _scenario_metrics(doc)
    raise ValueError("unrecognized result document: expected a "
                     "ScenarioResult to_json() or a BENCH --json document")


# ------------------------------------------------------------------- diff
def diff_metrics(old: dict[str, float], new: dict[str, float], *,
                 threshold: float = 0.10,
                 min_abs: float = DEFAULT_MIN_ABS) -> list[dict]:
    """Row per metric: name, old, new, rel delta, status. Status is one of
    ok | improved | regressed | added | removed."""
    rows = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            rows.append({"metric": name, "old": None, "new": n,
                         "delta": None, "status": "added"})
            continue
        if n is None:
            rows.append({"metric": name, "old": o, "new": None,
                         "delta": None, "status": "removed"})
            continue
        higher_better = name.rsplit("/", 1)[-1] in HIGHER_IS_BETTER
        delta = (n - o) / abs(o) if o else (0.0 if n == o else float("inf"))
        worse = (o - n) if higher_better else (n - o)
        rel_worse = worse / abs(o) if o else (float("inf") if worse > 0
                                              else 0.0)
        if worse > min_abs and rel_worse > threshold:
            status = "regressed"
        elif -worse > min_abs and -rel_worse > threshold:
            status = "improved"
        else:
            status = "ok"
        rows.append({"metric": name, "old": o, "new": n,
                     "delta": delta, "status": status})
    return rows


def render(rows: list[dict], *, markdown: bool = False,
           show_ok: bool = False) -> str:
    def fmt(v):
        if v is None:
            return "—"
        return f"{v:.6g}"

    def fmt_delta(d):
        if d is None:
            return "—"
        if d == float("inf"):
            return "+inf"
        return f"{d:+.1%}"

    interesting = [r for r in rows if show_ok or r["status"] != "ok"]
    n_reg = sum(r["status"] == "regressed" for r in rows)
    n_imp = sum(r["status"] == "improved" for r in rows)
    header = (f"bench-diff: {len(rows)} metrics compared, "
              f"{n_reg} regressed, {n_imp} improved")
    lines = []
    if markdown:
        lines.append(f"### {header}")
        lines.append("")
        if interesting:
            lines.append("| metric | old | new | delta | status |")
            lines.append("|---|---:|---:|---:|---|")
            for r in interesting:
                mark = {"regressed": "❌", "improved": "✅",
                        "added": "🆕", "removed": "⚠️"}.get(r["status"], "")
                lines.append(f"| `{r['metric']}` | {fmt(r['old'])} | "
                             f"{fmt(r['new'])} | {fmt_delta(r['delta'])} | "
                             f"{mark} {r['status']} |")
        else:
            lines.append("No changes beyond threshold.")
    else:
        lines.append(header)
        for r in interesting:
            lines.append(f"  {r['status']:9s} {r['metric']}: "
                         f"{fmt(r['old'])} -> {fmt(r['new'])} "
                         f"({fmt_delta(r['delta'])})")
    return "\n".join(lines)


# -------------------------------------------------------------------- cli
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline result JSON (previous run)")
    ap.add_argument("new", help="candidate result JSON (this run)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression gate (default 0.10 = 10%%)")
    ap.add_argument("--min-abs", type=float, default=DEFAULT_MIN_ABS,
                    help="ignore absolute deltas smaller than this")
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavoured markdown table")
    ap.add_argument("--show-ok", action="store_true",
                    help="list unchanged metrics too")
    ap.add_argument("--missing-ok", action="store_true",
                    help="exit 0 when the baseline file does not exist "
                         "(first run on a branch)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.old):
        msg = f"no baseline at {args.old}: nothing to diff against"
        print(f"### bench-diff\n\n{msg}" if args.markdown else msg)
        return 0 if args.missing_ok else 2
    try:
        with open(args.old) as f:
            old = extract_metrics(json.load(f))
        with open(args.new) as f:
            new = extract_metrics(json.load(f))
    except (OSError, ValueError, KeyError) as e:
        print(f"bench-diff: cannot read documents: {e}", file=sys.stderr)
        return 2

    rows = diff_metrics(old, new, threshold=args.threshold,
                        min_abs=args.min_abs)
    print(render(rows, markdown=args.markdown, show_ok=args.show_ok))
    return 1 if any(r["status"] == "regressed" for r in rows) else 0


if __name__ == "__main__":
    sys.exit(main())
