# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py            # full measurement run
#   python benchmarks/run.py --smoke    # tiny request counts: CI import check
#   python benchmarks/run.py --only fig5_concurrent,fig7_workflow
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run every figure with tiny request counts "
                         "(fast import-and-run check, not a measurement)")
    ap.add_argument("--only", default="",
                    help="comma-separated suite names to run")
    args = ap.parse_args(argv)

    from benchmarks import common
    if args.smoke:
        common.enable_smoke()

    from benchmarks import (appendix_platforms, engine_bench, fig3_exclusive,
                            fig4_utilization, fig5_concurrent, fig6_sharing,
                            fig7_workflow, kernel_bench, roofline_table)
    suites = [
        ("fig3_exclusive", fig3_exclusive.run),
        ("fig4_utilization", fig4_utilization.run),
        ("fig5_concurrent", fig5_concurrent.run),
        ("fig6_sharing", fig6_sharing.run),
        ("fig7_workflow", fig7_workflow.run),
        ("appendix_platforms", appendix_platforms.run),
        ("engine_bench", engine_bench.run),
        ("kernel_bench", kernel_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    if args.only:
        keep = {s.strip() for s in args.only.split(",") if s.strip()}
        known = {n for n, _ in suites}
        unknown = sorted(keep - known)
        if unknown:
            ap.error(f"unknown suite(s) {', '.join(unknown)}; "
                     f"available: {', '.join(sorted(known))}")
        suites = [(n, fn) for n, fn in suites if n in keep]

    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name}_FAILED,0.0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
