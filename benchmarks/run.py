# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py            # full measurement run
#   python benchmarks/run.py --smoke    # tiny request counts: CI import check
#   python benchmarks/run.py --only fig5_concurrent,fig7_workflow
#   python benchmarks/run.py --smoke --only kernel_bench,engine_bench \
#       --json BENCH_kernels.json       # CI perf-trajectory artifact
from __future__ import annotations

import argparse
import contextlib
import json
import platform
import signal
import sys
import time
import traceback

BENCH_SCHEMA_VERSION = 1

#: --smoke default for --row-timeout: a hung benchmark row fails fast with
#: its suite named instead of stalling CI until the job-level kill
SMOKE_ROW_TIMEOUT_S = 120.0


class RowTimeout(Exception):
    """A benchmark suite exceeded the per-row wall-clock budget."""


@contextlib.contextmanager
def row_deadline(suite: str, seconds: float):
    """Raise :class:`RowTimeout` (naming the suite) if the body runs longer
    than ``seconds``. SIGALRM-based, so it interrupts a wedged row rather
    than waiting for it; no-op where SIGALRM is unavailable (Windows) or
    the budget is 0."""
    if seconds <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _alarm(signum, frame):
        raise RowTimeout(f"suite {suite!r} exceeded the per-row "
                         f"{seconds:g}s timeout")

    prev = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, prev)


def _write_json(path: str, suites: list[tuple[str, list[str]]],
                smoke: bool) -> None:
    """Versioned bench document (the perf trajectory CI uploads per PR)."""
    entries = []
    for suite, lines in suites:
        for line in lines:
            name, us, derived = line.split(",", 2)
            entries.append({"suite": suite, "name": name,
                            "us_per_call": float(us), "derived": derived})
    doc = {
        "version": BENCH_SCHEMA_VERSION,
        "smoke": smoke,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "entries": entries,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {len(entries)} entries to {path}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run every figure with tiny request counts "
                         "(fast import-and-run check, not a measurement)")
    ap.add_argument("--only", default="",
                    help="comma-separated suite names to run")
    ap.add_argument("--json", default="",
                    help="also write collected rows to this path as a "
                         "versioned JSON document (perf-trajectory artifact)")
    ap.add_argument("--substrate", default="simulator",
                    choices=("simulator", "engine"),
                    help="execution substrate for Scenario-declared "
                         "figures: the analytic pod simulator (default) or "
                         "the real InferenceEngine under a virtual cost "
                         "clock")
    ap.add_argument("--row-timeout", type=float, default=None,
                    help="wall-clock seconds each suite may spend producing "
                         "a row before it is failed with RowTimeout (0 "
                         "disables; default: 0, or "
                         f"{SMOKE_ROW_TIMEOUT_S:.0f} under --smoke)")
    args = ap.parse_args(argv)
    row_timeout = args.row_timeout
    if row_timeout is None:
        row_timeout = SMOKE_ROW_TIMEOUT_S if args.smoke else 0.0

    from benchmarks import common
    if args.smoke:
        common.enable_smoke()
    common.set_substrate(args.substrate)

    from benchmarks import (appendix_platforms, engine_bench, fig3_exclusive,
                            fig4_utilization, fig5_concurrent, fig6_sharing,
                            fig7_workflow, fig_attribution, fig_memory,
                            fig_prefix, fig_resilience, fig_routing,
                            fig_stallfree, kernel_bench, roofline_table,
                            telemetry_bench)
    suites = [
        ("fig3_exclusive", fig3_exclusive.run),
        ("fig4_utilization", fig4_utilization.run),
        ("fig5_concurrent", fig5_concurrent.run),
        ("fig6_sharing", fig6_sharing.run),
        ("fig7_workflow", fig7_workflow.run),
        ("fig_attribution", fig_attribution.run),
        ("fig_memory", fig_memory.run),
        ("fig_prefix", fig_prefix.run),
        ("fig_resilience", fig_resilience.run),
        ("fig_routing", fig_routing.run),
        ("fig_stallfree", fig_stallfree.run),
        ("appendix_platforms", appendix_platforms.run),
        ("engine_bench", engine_bench.run),
        ("telemetry_bench", telemetry_bench.run),
        ("kernel_bench", kernel_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    if args.only:
        keep = {s.strip() for s in args.only.split(",") if s.strip()}
        known = {n for n, _ in suites}
        unknown = sorted(keep - known)
        if unknown:
            ap.error(f"unknown suite(s) {', '.join(unknown)}; "
                     f"available: {', '.join(sorted(known))}")
        suites = [(n, fn) for n, fn in suites if n in keep]

    print("name,us_per_call,derived")
    failures = []
    collected: list[tuple[str, list[str]]] = []
    for name, fn in suites:
        t0 = time.time()
        lines: list[str] = []
        collected.append((name, lines))  # keep partial rows on failure
        try:
            # the deadline is re-armed per row, so generator-style suites
            # get a true per-row budget; list-returning suites spend it all
            # producing the first "row" (the whole list)
            with row_deadline(name, row_timeout):
                it = iter(fn())
            while True:
                with row_deadline(name, row_timeout):
                    line = next(it, None)
                if line is None:
                    break
                print(line, flush=True)
                lines.append(line)
        except RowTimeout as e:
            failures.append(name)
            print(f"{name}_TIMEOUT,0.0,{e}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name}_FAILED,0.0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        _write_json(args.json, collected, args.smoke)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
