"""Paper Fig. 3: per-app latency (normalized to SLO) and SLO attainment when
running EXCLUSIVELY on the accelerator (upper bound) vs the host CPU (lower
bound). Pod analogue: full 256-chip mesh vs host fallback."""
from __future__ import annotations

from benchmarks.common import NUM_REQUESTS, STANDARD_APPS, row
from repro.core.apps import make_app
from repro.core.orchestrator import Orchestrator
from repro.roofline.hw import HOST_CPU, TPU_V5E


def run() -> list[str]:
    rows = []
    for device, chip in (("gpu", TPU_V5E), ("cpu", HOST_CPU)):
        for app_type in STANDARD_APPS:
            app = make_app(app_type)
            orch = Orchestrator(total_chips=256, chip=chip)
            n = NUM_REQUESTS[app_type] if device == "gpu" else max(
                NUM_REQUESTS[app_type] // 2, 3)
            res = orch.run_exclusive(app, n)
            rep = res.reports[app.name]
            st = rep.latency_stats()
            rows.append(row(
                f"fig3_exclusive_{device}_{app_type}",
                st.get("mean", 0.0) * 1e6,
                f"slo={rep.attainment:.3f};norm_lat={rep.normalized_latency():.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
