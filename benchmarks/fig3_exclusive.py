"""Paper Fig. 3: per-app latency (normalized to SLO) and SLO attainment when
running EXCLUSIVELY on the accelerator (upper bound) vs the host CPU (lower
bound). Pod analogue: full 256-chip mesh vs host fallback — declared as
exclusive-mode Scenarios."""
from __future__ import annotations

from benchmarks.common import (NUM_REQUESTS, STANDARD_APPS, TOTAL_CHIPS,
                               current_substrate, row)
from repro.bench import Scenario, ScenarioApp


def scenario(device: str) -> Scenario:
    chip = "tpu-v5e" if device == "gpu" else "host-cpu"
    scale = (lambda n: n) if device == "gpu" else (lambda n: max(n // 2, 3))
    return Scenario(
        name=f"fig3-exclusive-{device}", mode="exclusive", policy="greedy",
        total_chips=TOTAL_CHIPS, chip=chip, substrate=current_substrate(),
        apps=[ScenarioApp(app_type=t, num_requests=scale(NUM_REQUESTS[t]))
              for t in STANDARD_APPS])


def run() -> list[str]:
    rows = []
    for device in ("gpu", "cpu"):
        res = scenario(device).run()
        for app_type in STANDARD_APPS:
            rep = res.report(app_type)
            st = rep.latency_stats()
            rows.append(row(
                f"fig3_exclusive_{device}_{app_type}",
                st.get("mean", 0.0) * 1e6,
                f"slo={rep.attainment:.3f};norm_lat={rep.normalized_latency():.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
