"""Memory-contention scenario family (the paper's §4.3 model-sharing
inefficiency, reproduced as PAGE pressure instead of a static slot count).

One three-app workload — LiveCaptions (latency-critical), Chatbot, and the
KV-giant DeepResearch — runs under a shrinking KV page budget on BOTH
substrates:

* **simulator rows** — the analytic memory model: as the budget tightens,
  DeepResearch's resident context forces LRU evict-and-recompute cycles;
  evictions and recomputed tokens climb and the makespan degrades while
  the unconstrained run is untouched.
* **engine row** — the real paged InferenceEngine under a small pool:
  page-gated admission + preempt-to-evict, with
  ``pages_in_use``/``evictions``/``recompute_tokens`` surfaced from
  EngineStats into the schema-1.2 ``memory`` block.

Row value = makespan (the metric recompute moves); derived carries the
memory block — all virtual-clock deterministic, so the rows are diffable
in CI (``bench-diff``).
"""
from __future__ import annotations

from benchmarks.common import row, smoke_requests
from repro.bench import Scenario, ScenarioApp

#: full-scale page budgets (pages of 16 tokens): ample -> thrashing.
#: DeepResearch alone holds ~131k pages; 132k leaves slack for captions
#: only until chatbot bursts arrive; 131.1k thrashes.
SIM_BUDGETS = (None, 200_000, 132_000, 131_100)
#: tiny-vehicle pool (page_size 8): just above the largest single request
#: (~8 pages) and below the concurrent working set (~13), so admission
#: succeeds but decode growth forces preempt-to-evict cycles
ENGINE_BUDGET_PAGES = 10


def scenario(budget_pages, *, substrate: str = "simulator",
             policy: str = "slo_aware") -> Scenario:
    apps = [ScenarioApp("live_captions", num_requests=smoke_requests(10)),
            ScenarioApp("chatbot", num_requests=smoke_requests(4)),
            ScenarioApp("deep_research", num_requests=1)]
    return Scenario(
        name=f"mem-{budget_pages or 'unbounded'}-{substrate}",
        mode="concurrent", policy=policy, total_chips=64,
        substrate=substrate,
        kv_page_budget=budget_pages,
        page_size=16 if substrate == "simulator" else 8,
        apps=apps)


def engine_scenario() -> Scenario:
    """Small-pool engine run: captions + chatbot on one chip, pool sized to
    force preempt-to-evict while staying deterministic and CI-fast."""
    return Scenario(
        name="mem-engine", mode="engine", policy="chunked", total_chips=1,
        kv_page_budget=ENGINE_BUDGET_PAGES, page_size=8,
        apps=[ScenarioApp("live_captions", num_requests=smoke_requests(6)),
              ScenarioApp("chatbot", num_requests=smoke_requests(3))])


def _mem_derived(summary: dict) -> str:
    m = summary.get("memory", {})
    if not m:
        return "memory=unbounded"
    return (f"pages_in_use={m['pages_in_use']};"
            f"page_utilization={m['page_utilization']:.3f};"
            f"evictions={m['evictions']};"
            f"recompute_tokens={m['recompute_tokens']}")


def run() -> list[str]:
    rows = []
    for budget in SIM_BUDGETS:
        res = scenario(budget).run()
        s = res.sim.summary()
        cap = s["apps"]["live_captions"]
        rows.append(row(
            f"mem_sim_{budget or 'unbounded'}", s["makespan_s"] * 1e6,
            f"{_mem_derived(s)};captions_slo={cap['slo_attainment']:.3f};"
            f"captions_p99={cap.get('p99', 0.0):.4f}"))
    res = engine_scenario().run()
    s = res.sim.summary()
    cap = s["apps"]["live_captions"]
    rows.append(row(
        "mem_engine_paged", s["makespan_s"] * 1e6,
        f"{_mem_derived(s)};captions_slo={cap['slo_attainment']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
