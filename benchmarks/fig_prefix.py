"""Prefix-sharing scenario family: users-per-pool and prefill work vs.
shared-prefix fraction, on BOTH substrates.

Four concurrent chat sessions share a system prompt and re-send their full
history every turn (the ``conversation`` workload). Sweeping the system
block's size sweeps the SHARED FRACTION of each prompt; with the radix
prefix cache on (``prefix_cache: true``), both substrates should show, as
the shared fraction rises:

* **prefill_frac** (= 1 − hit_rate: the fraction of prompt tokens that
  still pay prefill FLOPs) strictly decreasing, and
* **pages_per_user** (peak KV footprint normalized by users × the
  per-user context a private cache would hold) strictly decreasing —
  equivalently ``users_per_pool`` pulling further ahead of
  ``users_per_pool_private`` (the no-sharing bound ``budget // context``
  at the same geometry): more concurrent users fit one page pool when
  their common prefix is stored once.

The engine rows come from the REAL trie + copy-on-write pages; the
simulator rows from the analytic mirror. Every block size is a multiple
of lcm(page_size=16, prefill_chunk=8), so the two substrates floor hits
onto the same grid — the ``sim_hit_rate`` field in each engine row's
derived column is the parity check (must agree within 5%; see
tests/test_conversation.py for the enforced version). All rows are
virtual-clock deterministic and diff in CI (``BENCH_prefix.json``).
"""
from __future__ import annotations

from benchmarks.common import row, smoke_enabled
from repro.bench import Scenario, ScenarioApp
from repro.bench.conversation import ConversationSpec

#: system-prompt sizes (tokens, multiples of 16): the shared-fraction axis
SYS_SWEEP = (64, 128, 256)
SYS_SWEEP_SMOKE = (64, 256)
USERS = 4
TURNS = 3
USER_TOKENS = 64
ASSISTANT_TOKENS = 64
#: simulator budget: full-scale tokens (pages of 16) — ample, no eviction
SIM_BUDGET_PAGES = 8192
#: engine budget: execution-vehicle pages — ample, no eviction
ENGINE_BUDGET_PAGES = 1024


def spec(sys_tokens: int) -> ConversationSpec:
    return ConversationSpec(turns=TURNS, system_tokens=sys_tokens,
                            user_tokens=USER_TOKENS,
                            assistant_tokens=ASSISTANT_TOKENS,
                            think_time_s=2.0)


def scenario(sys_tokens: int, *, substrate: str = "simulator",
             prefix_cache: bool = True) -> Scenario:
    return Scenario(
        name=f"prefix-sys{sys_tokens}-{'on' if prefix_cache else 'off'}"
             f"-{substrate}",
        mode="concurrent", policy="chunked", total_chips=8,
        substrate=substrate, prefix_cache=prefix_cache,
        kv_page_budget=(SIM_BUDGET_PAGES if substrate == "simulator"
                        else ENGINE_BUDGET_PAGES),
        page_size=16,
        apps=[ScenarioApp("conversation", name="chat", num_requests=USERS,
                          conversation=spec(sys_tokens))])


def _point_metrics(summary: dict, sys_tokens: int) -> dict:
    """Derived metrics for one sweep point from the schema-1.4 blocks."""
    sp = spec(sys_tokens)
    # per-user context a PRIVATE cache would hold at session end (tokens)
    foot = sp.max_prompt_tokens() + sp.assistant_tokens
    pfx = summary.get("prefix") or {}
    mem = summary.get("memory") or {}
    hit_rate = pfx.get("hit_rate", 0.0)
    # 'pages_in_use' in the schema-1.4 memory block is the PEAK page count
    peak = mem.get("pages_in_use", 0) * mem.get("page_size", 16)
    budget = mem.get("kv_token_budget", 0)
    per_user = peak / USERS if peak else float(USERS * foot)
    return {
        "shared_frac": sys_tokens / foot,
        "hit_rate": hit_rate,
        "prefill_frac": 1.0 - hit_rate,
        "pages_per_user": per_user / foot,      # normalized KV per user
        "users_per_pool": int(budget / per_user) if per_user else 0,
        "users_per_pool_private": int(budget / foot),
        "shared_pages": pfx.get("shared_pages", 0),
        "cow_forks": pfx.get("cow_forks", 0),
    }


def _derived(m: dict, extra: str = "") -> str:
    s = (f"shared_frac={m['shared_frac']:.3f};"
         f"hit_rate={m['hit_rate']:.3f};"
         f"prefill_frac={m['prefill_frac']:.3f};"
         f"pages_per_user={m['pages_per_user']:.3f};"
         f"users_per_pool={m['users_per_pool']};"
         f"users_per_pool_private={m['users_per_pool_private']};"
         f"shared_pages={m['shared_pages']};"
         f"cow_forks={m['cow_forks']}")
    return s + (";" + extra if extra else "")


def run() -> list[str]:
    sweep = SYS_SWEEP_SMOKE if smoke_enabled() else SYS_SWEEP
    rows = []
    sim_hit = {}
    for sys_tokens in sweep:
        s = scenario(sys_tokens).run().sim.summary()
        m = _point_metrics(s, sys_tokens)
        sim_hit[sys_tokens] = m["hit_rate"]
        rows.append(row(f"prefix_sim_sys{sys_tokens}",
                        s["makespan_s"] * 1e6, _derived(m)))
    # sharing-off simulator baseline at the largest point: the denominator
    # story (full prefill, full per-user footprint)
    s = scenario(sweep[-1], prefix_cache=False).run().sim.summary()
    m = _point_metrics(s, sweep[-1])
    rows.append(row(f"prefix_sim_off_sys{sweep[-1]}",
                    s["makespan_s"] * 1e6, _derived(m)))
    for sys_tokens in sweep:
        s = scenario(sys_tokens, substrate="engine").run().sim.summary()
        m = _point_metrics(s, sys_tokens)
        parity = (f"sim_hit_rate={sim_hit[sys_tokens]:.3f};"
                  f"parity_gap={abs(m['hit_rate'] - sim_hit[sys_tokens]):.4f}")
        rows.append(row(f"prefix_engine_sys{sys_tokens}",
                        s["makespan_s"] * 1e6, _derived(m, parity)))
    s = scenario(sweep[-1], substrate="engine",
                 prefix_cache=False).run().sim.summary()
    m = _point_metrics(s, sweep[-1])
    rows.append(row(f"prefix_engine_off_sys{sweep[-1]}",
                    s["makespan_s"] * 1e6, _derived(m)))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
