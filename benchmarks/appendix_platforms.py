"""Paper §4.4 / Appendix C analogue: the same concurrent workload on a
second platform (their MacBook M1 Pro → our TPU v5p pod, 64 chips) —
different compute/bandwidth balance shifts which apps suffer under
contention, mirroring the paper's observation that scheduling behaviour is
platform-dependent."""
from __future__ import annotations

from benchmarks.common import NUM_REQUESTS, STANDARD_APPS, row
from repro.core.apps import make_app
from repro.core.orchestrator import Orchestrator
from repro.roofline.hw import TPU_V5E, TPU_V5P


def run() -> list[str]:
    rows = []
    apps = [make_app(t) for t in STANDARD_APPS]
    nreq = {a.name: NUM_REQUESTS[a.name] for a in apps}
    for chip, chips in ((TPU_V5E, 256), (TPU_V5P, 64)):
        for strategy in ("greedy", "slo_aware"):
            orch = Orchestrator(total_chips=chips, strategy=strategy,
                                chip=chip)
            res = orch.run_concurrent(apps, nreq)
            for a in apps:
                rep = res.reports[a.name]
                rows.append(row(
                    f"platform_{chip.name}_{strategy}_{a.name}",
                    (rep.latency_stats().get("mean", 0.0)) * 1e6,
                    f"slo={rep.attainment:.3f};"
                    f"util={res.utilization():.3f};"
                    f"energy_kj={res.energy_j() / 1e3:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
