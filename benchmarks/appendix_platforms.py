"""Paper §4.4 / Appendix C analogue: the same concurrent workload on a
second platform (their MacBook M1 Pro → our TPU v5p pod, 64 chips) —
different compute/bandwidth balance shifts which apps suffer under
contention, mirroring the paper's observation that scheduling behaviour is
platform-dependent."""
from __future__ import annotations

from benchmarks.common import (NUM_REQUESTS, STANDARD_APPS,
                               current_substrate, row)
from repro.bench import Scenario, ScenarioApp


def run() -> list[str]:
    rows = []
    for chip, chips in (("tpu-v5e", 256), ("tpu-v5p", 64)):
        for policy in ("greedy", "slo_aware"):
            sc = Scenario(
                name=f"platform-{chip}-{policy}", mode="concurrent",
                policy=policy, total_chips=chips, chip=chip,
                substrate=current_substrate(),
                apps=[ScenarioApp(app_type=t, num_requests=NUM_REQUESTS[t])
                      for t in STANDARD_APPS])
            sim = sc.run().sim
            for name in STANDARD_APPS:
                rep = sim.reports[name]
                rows.append(row(
                    f"platform_{chip}_{policy}_{name}",
                    (rep.latency_stats().get("mean", 0.0)) * 1e6,
                    f"slo={rep.attainment:.3f};"
                    f"util={sim.utilization():.3f};"
                    f"energy_kj={sim.energy_j() / 1e3:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
