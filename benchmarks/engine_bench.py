"""Engine-level benchmark: chunked prefill vs fcfs decode-stall (real JAX
execution on a reduced model with a virtual cost clock) — the engine-level
view of the paper's starvation finding — plus dispatch accounting for the
batched-prefill hot path (one ``prefill_chunk`` dispatch per chunk vs the
token-stepped baseline's one ``decode_step`` dispatch per prompt token)."""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from benchmarks.common import row
from repro.configs.registry import CONFIGS
from repro.models.factory import build_model
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request


def _dispatch_case(model, params, cfg, *, prompt_len: int = 64,
                   chunk: int = 16) -> str:
    """Jitted model dispatches per request for prompt_len/chunk.

    The token-stepped seed issued ``prompt_len`` prefill dispatches (one
    decode_step per token); batched chunked prefill issues
    ``ceil(prompt_len/chunk)``."""
    def cost(kind, tokens):
        return {"prefill": 0.001 * tokens, "decode": 0.001}[kind]

    eng = InferenceEngine(model, max_slots=2, max_seq=prompt_len + 16,
                          policy="chunked", prefill_chunk=chunk,
                          step_cost_s=cost)
    eng.load_params(params)
    rng = np.random.default_rng(0)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
                       4, arrival_s=0.0))
    eng.run()
    got = eng.stats.prefill_dispatches
    baseline = prompt_len  # seed: one jitted decode_step per prompt token
    assert got <= math.ceil(prompt_len / chunk), (got, prompt_len, chunk)
    return row(f"engine_dispatch_p{prompt_len}_c{chunk}", float(got),
               f"prefill_dispatches={got};token_stepped_baseline={baseline};"
               f"ratio={baseline / got:.1f};decode_syncs={eng.stats.decode_syncs}")


def run() -> list[str]:
    cfg = dataclasses.replace(CONFIGS["tinyllama-1.1b"].reduced(),
                              num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def cost(kind, tokens):
        return {"prefill": 0.01 * tokens, "decode": 0.002}[kind]

    rows = [_dispatch_case(model, params, cfg)]
    for policy in ("fcfs", "chunked", "slo_aware"):
        eng = InferenceEngine(model, max_slots=2, max_seq=192, policy=policy,
                              prefill_chunk=8, step_cost_s=cost)
        eng.load_params(params)
        rng = np.random.default_rng(0)
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           24, arrival_s=0.0))
        # long prompt lands mid-decode: fcfs stalls the active stream
        eng.submit(Request(1, rng.integers(0, cfg.vocab_size, 120).astype(np.int32),
                           4, arrival_s=0.07, deadline_s=10.0))
        done = eng.run()
        ttfts = [r.ttft for r in done if r.ttft is not None]
        rows.append(row(
            f"engine_{policy}",
            eng.stats.max_decode_gap_s * 1e6,
            f"max_decode_gap_s={eng.stats.max_decode_gap_s:.3f};"
            f"mean_ttft_s={np.mean(ttfts):.3f};"
            f"decode_tokens={eng.stats.decode_tokens}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
