"""Engine-level benchmark, now declared as engine-substrate Scenarios: the
same policy registry the pod simulator consumes drives the REAL
InferenceEngine (continuous batching, chunked prefill, slot admission)
under a virtual cost clock — the engine-level view of the paper's
starvation finding from one Scenario spec. Also keeps the dispatch
accounting row for the batched-prefill hot path (one ``prefill_chunk``
dispatch per chunk vs the token-stepped baseline's one ``decode_step``
dispatch per prompt token)."""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import row, smoke_requests
from repro.bench import Scenario, ScenarioApp
from repro.bench.engine_runner import engine_model
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request

POLICIES = ("fcfs", "chunked", "slo_aware", "preemptive_priority")


def _dispatch_case(model, params, cfg, *, prompt_len: int = 64,
                   chunk: int = 16) -> str:
    """Jitted model dispatches per request for prompt_len/chunk.

    The token-stepped seed issued ``prompt_len`` prefill dispatches (one
    decode_step per token); batched chunked prefill issues
    ``ceil(prompt_len/chunk)``."""
    def cost(kind, tokens):
        return {"prefill": 0.001 * tokens, "decode": 0.001}[kind]

    eng = InferenceEngine(model, max_slots=2, max_seq=prompt_len + 16,
                          policy="chunked", prefill_chunk=chunk,
                          step_cost_s=cost)
    eng.load_params(params)
    rng = np.random.default_rng(0)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
                       4, arrival_s=0.0))
    eng.run()
    got = eng.stats.prefill_dispatches
    baseline = prompt_len  # seed: one jitted decode_step per prompt token
    assert got <= math.ceil(prompt_len / chunk), (got, prompt_len, chunk)
    return row(f"engine_dispatch_p{prompt_len}_c{chunk}", float(got),
               f"prefill_dispatches={got};token_stepped_baseline={baseline};"
               f"ratio={baseline / got:.1f};decode_syncs={eng.stats.decode_syncs}")


def scenario(policy: str) -> Scenario:
    """A 12B chatbot's long prefill contending with LiveCaptions decode on
    a single-chip engine (the paper's starvation mechanism at consumer
    scale): fcfs stalls every caption for whole prompts; chunked policies
    bound the stall near ``chunk_target_s``."""
    return Scenario(
        name=f"engine-{policy}", mode="engine", policy=policy,
        total_chips=1,
        apps=[ScenarioApp("live_captions", num_requests=smoke_requests(8)),
              ScenarioApp("chatbot", arch="stablelm-12b",
                          num_requests=smoke_requests(3))])


def run() -> list[str]:
    # same cached reduced model (and jitted executables) the engine
    # substrate runs on — no duplicate build/compile
    model, params, cfg = engine_model()

    rows = [_dispatch_case(model, params, cfg)]
    for policy in POLICIES:
        res = scenario(policy).run()
        sim = res.sim
        stats = next(iter(res.engine_stats.values()))
        cap = sim.reports["live_captions"]
        # row value = captions mean latency: the metric the prefill stall
        # actually moves (whole-prompt fcfs inflates it several-fold vs
        # chunked), deterministic under the virtual clock → diffable in CI
        rows.append(row(
            f"engine_{policy}",
            cap.latency_stats()["mean"] * 1e6,
            f"captions_slo={cap.attainment:.3f};"
            f"max_decode_gap_s={stats.max_decode_gap_s:.3f};"
            f"makespan_s={sim.makespan_s:.2f};"
            f"prefill_dispatches={stats.prefill_dispatches};"
            f"decode_syncs={stats.decode_syncs};"
            f"pages_in_use={stats.pages_in_use};"
            f"evictions={stats.evictions};"
            f"recompute_tokens={stats.recompute_tokens}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
