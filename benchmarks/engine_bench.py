"""Engine-level benchmark: chunked prefill vs fcfs decode-stall (real JAX
execution on a reduced model with a virtual cost clock) — the engine-level
view of the paper's starvation finding."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row
from repro.configs.registry import CONFIGS
from repro.models.factory import build_model
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request


def run() -> list[str]:
    cfg = dataclasses.replace(CONFIGS["tinyllama-1.1b"].reduced(),
                              num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def cost(kind, tokens):
        return {"prefill": 0.01 * tokens, "decode": 0.002}[kind]

    rows = []
    for policy in ("fcfs", "chunked", "slo_aware"):
        eng = InferenceEngine(model, max_slots=2, max_seq=192, policy=policy,
                              prefill_chunk=8, step_cost_s=cost)
        eng.load_params(params)
        rng = np.random.default_rng(0)
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           24, arrival_s=0.0))
        # long prompt lands mid-decode: fcfs stalls the active stream
        eng.submit(Request(1, rng.integers(0, cfg.vocab_size, 120).astype(np.int32),
                           4, arrival_s=0.07, deadline_s=10.0))
        done = eng.run()
        ttfts = [r.ttft for r in done if r.ttft is not None]
        rows.append(row(
            f"engine_{policy}",
            eng.stats.max_decode_gap_s * 1e6,
            f"max_decode_gap_s={eng.stats.max_decode_gap_s:.3f};"
            f"mean_ttft_s={np.mean(ttfts):.3f};"
            f"decode_tokens={eng.stats.decode_tokens}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
