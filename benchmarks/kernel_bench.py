"""Kernel microbenchmarks (paper §4.1's per-kernel analysis analogue):
real wall time of the jnp lowering on CPU + analytic v5e roofline time for
the Pallas kernel's tile schedule, plus the roofline autotuner's chosen
block configs (kernels/autotune.py) so BENCH_kernels.json records the
tuned schedule alongside the timings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.kernels import autotune, ref
from repro.models.attention import decode_attention_jnp, flash_attention_jnp
from repro.roofline.hw import TPU_V5E


def _flash_case(b, h, kv, s, d):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    return q, k, v


def _v5e_attention_time(b, h, s, d, causal=True) -> float:
    flops = 4.0 * b * h * s * s * d * (0.5 if causal else 1.0)
    byts = 2.0 * b * s * (3 * h * d + h * d)
    return max(flops / TPU_V5E.peak_flops_bf16, byts / TPU_V5E.hbm_bandwidth)


def run() -> list[str]:
    rows = []
    for (b, h, kv, s, d) in [(1, 4, 2, 256, 64), (1, 8, 4, 512, 64)]:
        q, k, v = _flash_case(b, h, kv, s, d)
        fn = jax.jit(lambda q, k, v: flash_attention_jnp(
            q, k, v, causal=True, q_block=128, kv_block=128))
        us = time_call(lambda: jax.block_until_ready(fn(q, k, v)))
        v5e = _v5e_attention_time(b, h, s, d) * 1e6
        rows.append(row(f"kernel_flash_b{b}h{h}s{s}d{d}", us,
                        f"v5e_roofline_us={v5e:.2f}"))
    # decode
    ks = jax.random.split(jax.random.key(1), 3)
    b, h, kv, s, d = 4, 8, 4, 2048, 64
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, kv, d))
    vc = jax.random.normal(ks[2], (b, s, kv, d))
    lengths = jnp.full((b,), s)
    fn = jax.jit(decode_attention_jnp)
    us = time_call(lambda: jax.block_until_ready(fn(q, kc, vc, lengths)))
    kv_bytes = 2.0 * b * s * kv * d * 2
    rows.append(row(f"kernel_decode_b{b}s{s}", us,
                    f"v5e_kv_read_us={kv_bytes / TPU_V5E.hbm_bandwidth * 1e6:.2f}"))
    # ssd chunk
    m, qq, hh, p, n = 4, 64, 16, 32, 64
    kk = jax.random.split(jax.random.key(2), 4)
    x = jax.random.normal(kk[0], (m, qq, hh, p))
    dt = jax.nn.softplus(jax.random.normal(kk[1], (m, qq, hh)))
    cum = jnp.cumsum(-0.1 * dt, axis=1)
    b_ = jax.random.normal(kk[2], (m, qq, n))
    c_ = jax.random.normal(kk[3], (m, qq, n))
    fn = jax.jit(jax.vmap(ref.ssd_chunk_ref))
    us = time_call(lambda: jax.block_until_ready(fn(x, dt, cum, b_, c_)))
    flops = 2.0 * m * qq * qq * (hh * p + n)
    rows.append(row(f"kernel_ssd_m{m}q{qq}h{hh}", us,
                    f"v5e_roofline_us={flops / TPU_V5E.peak_flops_bf16 * 1e6:.3f}"))

    # autotuner: chosen block configs + roofline estimates per shape bucket
    for kernel, shape in [
        ("decode_attention", {"b": 4, "kv": 4, "g": 2, "s": 2048, "d": 64}),
        ("decode_attention", {"b": 1, "kv": 8, "g": 4, "s": 32768, "d": 128}),
        ("flash_attention", {"b": 1, "h": 8, "kv": 4, "sq": 4096,
                             "skv": 4096, "d": 64, "causal": True}),
        ("ssd_chunk_scan", {"m": 8, "q": 256, "h": 64, "p": 64, "n": 128}),
    ]:
        blocks = autotune.best_config(kernel, shape)
        est = autotune.roofline_estimate(kernel, shape, blocks) * 1e6
        desc = "-".join(f"{k}{v}" for k, v in sorted(shape.items()))
        cfgs = ";".join(f"{k}={v}" for k, v in sorted(blocks.items()))
        rows.append(row(f"autotune_{kernel}_{desc}", est,
                        f"{cfgs};v5e_roofline_us={est:.2f}"))

    # roofline-verified decode batch per app model (ROADMAP item): the batch
    # where the target chip crosses from HBM-bound to compute-bound
    from repro.configs.registry import CONFIGS
    from repro.distributed.autotune import best_batch_size
    for arch in ("tinyllama-1.1b", "qwen3-14b", "mamba2-1.3b"):
        b = best_batch_size(CONFIGS[arch])
        rows.append(row(f"autotune_batch_{arch}", float(b),
                        f"roofline_decode_batch={b}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
