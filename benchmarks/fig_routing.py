"""Routing-tier scenario family: replica fleets behind every pluggable
routing policy, on BOTH substrates.

Concurrent chat sessions (the ``conversation`` workload, shared system
prompt, growing history) are served by ``replicas: 4`` copies of one
partition. Sweeping ``routing:`` across the registry compares, at a fixed
(workload, seed):

* **hit_rate** — the prefix-cache hit rate; ``prefix_aware`` probes each
  replica's radix trie and must be >= ``round_robin``, which scatters a
  session's turns across replicas and re-pays their prefill;
* **slo_attainment** — mean per-app attainment (>= for prefix_aware too);
* **imbalance** — coefficient of variation of routed tokens across the
  fleet (the load-balancing lens: p2c/least-outstanding minimize it,
  affinity-seeking policies trade it away);
* **affinity_hits / routed** — how often the policy found a warm replica.

A second axis holds ``prefix_aware`` fixed and sweeps ``replicas`` 1→4.
Engine rows rerun the policy sweep on the real engines (one
InferenceEngine per replica, radix-trie probes via ``prefix_peek``) and
carry ``parity_gap`` — the relative makespan gap vs. the simulator row,
required <= 5%. All rows are virtual-clock deterministic and diff in CI
(``BENCH_routing.json``). No KV page budget: the simulator pools pages
globally while the engine splits them per replica, so a binding budget
is the one knob the substrates legitimately disagree on.
"""
from __future__ import annotations

from benchmarks.common import row, smoke_enabled
from repro.bench import Scenario, ScenarioApp
from repro.bench.conversation import ConversationSpec

POLICIES = ("round_robin", "least_outstanding_tokens",
            "power_of_two_choices", "session_affinity", "prefix_aware")
POLICIES_SMOKE = ("round_robin", "prefix_aware")
REPLICAS = 4
REPLICA_SWEEP = (1, 2, 4)
REPLICA_SWEEP_SMOKE = (1, 4)
USERS = 6
TURNS = 3
SEED = 7


def spec() -> ConversationSpec:
    return ConversationSpec(turns=TURNS, system_tokens=192, user_tokens=48,
                            assistant_tokens=48, think_time_s=1.0)


def scenario(routing: str, replicas: int = REPLICAS, *,
             substrate: str = "simulator") -> Scenario:
    return Scenario(
        name=f"routing-{routing}-r{replicas}-{substrate}",
        mode="concurrent", policy="chunked", total_chips=16,
        substrate=substrate, seed=SEED, prefix_cache=True, page_size=16,
        replicas=replicas, routing=routing,
        apps=[ScenarioApp("conversation", name="chat", num_requests=USERS,
                          conversation=spec())])


def _point_metrics(summary: dict) -> dict:
    """Derived metrics for one sweep point from the schema-1.6 blocks."""
    rt = summary.get("routing") or {}
    pfx = summary.get("prefix") or {}
    apps = summary.get("apps") or {}
    att = (sum(a["slo_attainment"] for a in apps.values()) / len(apps)
           if apps else 0.0)
    return {
        "replicas": rt.get("replicas", 1),
        "routed": rt.get("routed", 0),
        "affinity_hits": rt.get("affinity_hits", 0),
        "imbalance": rt.get("imbalance", 0.0),
        "hit_rate": pfx.get("hit_rate", 0.0),
        "slo_attainment": att,
    }


def _derived(m: dict, extra: str = "") -> str:
    s = (f"replicas={m['replicas']};"
         f"hit_rate={m['hit_rate']:.3f};"
         f"slo_attainment={m['slo_attainment']:.3f};"
         f"imbalance={m['imbalance']:.3f};"
         f"affinity_hits={m['affinity_hits']};"
         f"routed={m['routed']}")
    return s + (";" + extra if extra else "")


def run() -> list[str]:
    policies = POLICIES_SMOKE if smoke_enabled() else POLICIES
    reps = REPLICA_SWEEP_SMOKE if smoke_enabled() else REPLICA_SWEEP
    rows = []
    sim_makespan = {}
    for pol in policies:
        s = scenario(pol).run().sim.summary()
        sim_makespan[pol] = s["makespan_s"]
        rows.append(row(f"routing_sim_{pol}",
                        s["makespan_s"] * 1e6, _derived(_point_metrics(s))))
    for n in reps:
        s = scenario("prefix_aware", n).run().sim.summary()
        rows.append(row(f"routing_sim_prefix_aware_r{n}",
                        s["makespan_s"] * 1e6, _derived(_point_metrics(s))))
    for pol in policies:
        s = scenario(pol, substrate="engine").run().sim.summary()
        gap = abs(s["makespan_s"] - sim_makespan[pol]) / sim_makespan[pol]
        rows.append(row(f"routing_engine_{pol}", s["makespan_s"] * 1e6,
                        _derived(_point_metrics(s),
                                 f"parity_gap={gap:.4f}")))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
