"""Resilience scenario family: goodput under swept fault intensity, on
BOTH substrates.

A latency-critical app (live captions) shares the pod with an interactive
chatbot while the ``repro.resilience`` layer injects a co-ordinated fault
storm whose severity scales with one knob ``x`` in [0, 1]:

* **thermal_throttle** — clocks derate to ``1 - 0.6x`` of nominal for a
  long window (sustained-load throttling on a fanless device),
* **engine_stall (crash)** — the engine blacks out for ``6x`` seconds and
  loses all in-flight state; recovery replays the killed requests,
* **memory_spike** — an external app steals ``0.5x`` of the KV page pool
  at runtime, forcing live eviction (refcounted shared prefix pages are
  structurally protected),
* **client_timeout** — clients cap their wait and retry with exponential
  backoff, cancelling past the deadline,

with ``shed_on_slo`` arming admission-time load shedding. ``x = 0`` is the
clean baseline: its ``faults`` block must be zero-filled and its document
identical to a scenario with no ``faults:`` key at all.

The headline metric is **goodput** (completed-within-SLO / issued): the
paper's resilience story is that it should degrade *gracefully* —
monotonically (within noise) in ``x``, never collapsing to zero while the
shedding controller keeps the survivors inside their SLOs. Engine rows
re-run the same seeded schedule on the real InferenceEngine; the clean
point doubles as the substrate-parity check. All rows are virtual-clock
deterministic and diff in CI (``BENCH_resilience.json``).
"""
from __future__ import annotations

from benchmarks.common import row, smoke_enabled
from repro.bench import Scenario, ScenarioApp

#: fault-intensity axis (0 = clean baseline)
INTENSITY_SWEEP = (0.0, 0.4, 0.7, 1.0)
INTENSITY_SWEEP_SMOKE = (0.0, 1.0)
NUM_CAPTIONS = 12
NUM_CHAT = 4
NUM_CAPTIONS_SMOKE = 4
NUM_CHAT_SMOKE = 2
TOTAL_CHIPS = 64
#: memory_spike needs a finite pool to steal from
SIM_BUDGET_PAGES = 2048
ENGINE_BUDGET_PAGES = 256


def faults_at(x: float) -> list[dict]:
    """The fault storm at intensity ``x`` (empty at 0: clean baseline)."""
    if x <= 0.0:
        return []
    return [
        {"kind": "thermal_throttle", "start_s": 2.0, "duration_s": 30.0,
         "derate": 1.0 - 0.6 * x},
        {"kind": "engine_stall", "start_s": 8.0, "duration_s": 6.0 * x,
         "crash": True},
        {"kind": "memory_spike", "start_s": 4.0, "duration_s": 20.0,
         "steal_fraction": 0.5 * x},
        {"kind": "client_timeout", "timeout_s": 20.0, "max_retries": 2,
         "backoff_base_s": 0.5, "backoff_cap_s": 4.0},
    ]


def scenario(x: float, *, substrate: str = "simulator") -> Scenario:
    smoke = smoke_enabled()
    return Scenario(
        name=f"resilience-x{x:.1f}-{substrate}",
        mode="concurrent", policy="slo_aware", total_chips=TOTAL_CHIPS,
        substrate=substrate, seed=7, page_size=16,
        kv_page_budget=(SIM_BUDGET_PAGES if substrate == "simulator"
                        else ENGINE_BUDGET_PAGES),
        faults=faults_at(x),
        shed_on_slo=({"attainment": 0.7, "window": 8, "action": "shed"}
                     if x > 0.0 else None),
        apps=[ScenarioApp("live_captions",
                          num_requests=(NUM_CAPTIONS_SMOKE if smoke
                                        else NUM_CAPTIONS)),
              ScenarioApp("chatbot",
                          num_requests=(NUM_CHAT_SMOKE if smoke
                                        else NUM_CHAT))])


def _derived(fb: dict, extra: str = "") -> str:
    s = (f"goodput={fb['goodput']:.3f};"
         f"injected={fb['injected']};"
         f"issued={fb['issued']};"
         f"completed_ok={fb['completed_ok']};"
         f"retries={fb['retries']};"
         f"timeouts={fb['timeouts']};"
         f"cancels={fb['cancels']};"
         f"sheds={fb['sheds']};"
         f"replays={fb['replays']};"
         f"ttr_s={fb['time_to_recover_s']:.3f}")
    return s + (";" + extra if extra else "")


def run() -> list[str]:
    sweep = INTENSITY_SWEEP_SMOKE if smoke_enabled() else INTENSITY_SWEEP
    rows = []
    sim_goodput = {}
    for x in sweep:
        s = scenario(x).run().sim.summary()
        fb = s["faults"]
        sim_goodput[x] = fb["goodput"]
        rows.append(row(f"resilience_sim_x{x:.1f}",
                        s["makespan_s"] * 1e6, _derived(fb)))
    for x in sweep:
        s = scenario(x, substrate="engine").run().sim.summary()
        fb = s["faults"]
        parity = (f"sim_goodput={sim_goodput[x]:.3f};"
                  f"parity_gap={abs(fb['goodput'] - sim_goodput[x]):.4f}")
        rows.append(row(f"resilience_engine_x{x:.1f}",
                        s["makespan_s"] * 1e6, _derived(fb, parity)))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
