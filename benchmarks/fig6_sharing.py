"""Paper Fig. 6 / §4.2.1: static model sharing via one inference server —
Chatbot vs Chatbot-KVCache-CPU while DeepResearch shares the model."""
from __future__ import annotations

from benchmarks.common import row
from repro.core.orchestrator import Orchestrator
from repro.core.sharing import shared_chatbot_apps


def run() -> list[str]:
    rows = []
    for kv in ("device", "host"):
        apps = shared_chatbot_apps(kv)
        nreq = {a.name: (10 if "Chatbot" in a.name else 1) for a in apps}
        orch = Orchestrator(total_chips=256, strategy="greedy")
        res = orch.run_concurrent(apps, nreq)
        chat = next(a.name for a in apps if "Chatbot" in a.name)
        rep = res.reports[chat]
        st = rep.latency_stats()
        rows.append(row(
            f"fig6_sharing_kv_{kv}_{chat}",
            st.get("mean", 0.0) * 1e6,
            f"slo={rep.attainment:.3f};"
            f"norm_lat={rep.normalized_latency():.3f};"
            f"util={res.utilization():.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
