"""Paper Fig. 6 / §4.2.1: static model sharing via one inference server —
Chatbot vs Chatbot-KVCache-CPU while DeepResearch shares the model. The
shared-server pair is declared as a Scenario: DeepResearch rides on the
chatbot's architecture, and kv_cache=host moves attention to the host.
Telemetry is on: the derived column carries the HBM-bandwidth and SMOCC
means, showing the host-KV variant starving device bandwidth."""
from __future__ import annotations

from benchmarks.common import (TOTAL_CHIPS, current_substrate, row,
                               smoke_requests)
from repro.bench import Scenario, ScenarioApp
from repro.core.apps import DEFAULT_ARCH
from repro.telemetry import UtilizationTimeline


def scenario(kv: str) -> Scenario:
    host = kv == "host"
    chat = "Chatbot-KVCache-CPU" if host else "Chatbot"
    shared_arch = DEFAULT_ARCH["chatbot"]   # one server backs both apps
    return Scenario(
        name=f"fig6-sharing-kv-{kv}", mode="concurrent", policy="greedy",
        total_chips=TOTAL_CHIPS, substrate=current_substrate(),
        telemetry=True,
        apps=[ScenarioApp("chatbot", name=chat, kv_cache_on_host=host,
                          num_requests=smoke_requests(10)),
              ScenarioApp("deep_research", name="DeepResearch",
                          arch=shared_arch, kv_cache_on_host=host,
                          num_requests=1)])


def run() -> list[str]:
    rows = []
    for kv in ("device", "host"):
        sc = scenario(kv)
        res = sc.run()
        chat = next(a.name for a in sc.apps if "Chatbot" in a.name)
        rep = res.report(chat)
        st = rep.latency_stats()
        tl = UtilizationTimeline.from_sim(res.sim, bins=100)
        rows.append(row(
            f"fig6_sharing_kv_{kv}_{chat}",
            st.get("mean", 0.0) * 1e6,
            f"slo={rep.attainment:.3f};"
            f"norm_lat={rep.normalized_latency():.3f};"
            f"util={res.sim.utilization():.3f};"
            f"smocc={tl.smocc_mean:.3f};"
            f"mean_bw_gbs={tl.bandwidth_gbs_mean:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
