"""Paper Fig. 4: per-app accelerator utilization running exclusively.
TPU translation: SMACT ≙ reserved-chip fraction, SMOCC ≙ reserved ×
roofline achievement computed from each dispatch's ACTUAL FLOPs/bytes
(repro.telemetry — the hard-coded occupancy constant is gone); plus the
memory-bandwidth timeline and the power model (paper Fig. 8). The rows
come from the same telemetry timeline either substrate records, so
``benchmarks/run.py --substrate engine`` measures the real
InferenceEngine's utilization with identical code."""
from __future__ import annotations

from benchmarks.common import (NUM_REQUESTS, STANDARD_APPS, TOTAL_CHIPS,
                               current_substrate, row)
from repro.bench import Scenario, ScenarioApp
from repro.telemetry import UtilizationTimeline


def scenario(substrate: str) -> Scenario:
    return Scenario(
        name="fig4-utilization", mode="exclusive", policy="greedy",
        total_chips=TOTAL_CHIPS, substrate=substrate, telemetry=True,
        apps=[ScenarioApp(app_type=t, num_requests=NUM_REQUESTS[t])
              for t in STANDARD_APPS])


def run() -> list[str]:
    substrate = current_substrate()
    res = scenario(substrate).run()
    rows = []
    for app_type in STANDARD_APPS:
        sim = res.sims[app_type]
        tl = UtilizationTimeline.from_sim(sim, bins=100)
        rows.append(row(
            f"fig4_utilization_{app_type}",
            sim.makespan_s * 1e6,
            f"smact={tl.smact_mean:.3f};smocc={tl.smocc_mean:.3f};"
            f"mean_power_w={tl.power_w_mean:.0f};"
            f"mean_bw_gbs={tl.bandwidth_gbs_mean:.1f};"
            f"energy_kj={sim.energy_j() / 1e3:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
