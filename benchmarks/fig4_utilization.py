"""Paper Fig. 4: per-app accelerator utilization running exclusively.
TPU translation: SMACT ≙ reserved-chip fraction, SMOCC ≙ reserved ×
roofline-achievement; plus the power model (paper Fig. 8)."""
from __future__ import annotations

from benchmarks.common import (NUM_REQUESTS, STANDARD_APPS, TOTAL_CHIPS,
                               current_substrate, row)
from repro.bench import Scenario, ScenarioApp
from repro.monitor.metrics import UtilizationTimeline


def run() -> list[str]:
    scenario = Scenario(
        name="fig4-utilization", mode="exclusive", policy="greedy",
        total_chips=TOTAL_CHIPS, substrate=current_substrate(),
        apps=[ScenarioApp(app_type=t, num_requests=NUM_REQUESTS[t])
              for t in STANDARD_APPS])
    res = scenario.run()
    rows = []
    for app_type in STANDARD_APPS:
        sim = res.sims[app_type]
        tl = UtilizationTimeline.from_sim(sim, bins=100)
        smact = sum(tl.smact) / len(tl.smact)
        smocc = sum(tl.smocc) / len(tl.smocc)
        mean_pw = sum(tl.power_w) / len(tl.power_w)
        rows.append(row(
            f"fig4_utilization_{app_type}",
            sim.makespan_s * 1e6,
            f"smact={smact:.3f};smocc={smocc:.3f};mean_power_w={mean_pw:.0f};"
            f"energy_kj={sim.energy_j() / 1e3:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
