"""Paper Fig. 4: per-app accelerator utilization running exclusively.
TPU translation: SMACT ≙ reserved-chip fraction, SMOCC ≙ reserved ×
roofline-achievement; plus the power model (paper Fig. 8)."""
from __future__ import annotations

from benchmarks.common import NUM_REQUESTS, STANDARD_APPS, row
from repro.core.apps import make_app
from repro.core.orchestrator import Orchestrator
from repro.monitor.metrics import UtilizationTimeline


def run() -> list[str]:
    rows = []
    for app_type in STANDARD_APPS:
        app = make_app(app_type)
        orch = Orchestrator(total_chips=256)
        res = orch.run_exclusive(app, NUM_REQUESTS[app_type])
        tl = UtilizationTimeline.from_sim(res, bins=100)
        smact = sum(tl.smact) / len(tl.smact)
        smocc = sum(tl.smocc) / len(tl.smocc)
        mean_pw = sum(tl.power_w) / len(tl.power_w)
        rows.append(row(
            f"fig4_utilization_{app_type}",
            res.makespan_s * 1e6,
            f"smact={smact:.3f};smocc={smocc:.3f};mean_power_w={mean_pw:.0f};"
            f"energy_kj={res.energy_j() / 1e3:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
