"""Shared helpers for the per-figure benchmarks. Every benchmark emits
``name,us_per_call,derived`` CSV rows (harness contract).

Figure benchmarks are declared as :class:`repro.bench.Scenario` specs; this
module centralizes the standard app set, request counts, and the ``--smoke``
fast path (tiny request counts so CI import-checks every figure quickly —
enable via ``enable_smoke()`` or the CONSUMERBENCH_SMOKE=1 env var).
"""
from __future__ import annotations

import os
import time
from typing import Callable

from repro.bench import Scenario, ScenarioApp


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (real execution)."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


STANDARD_APPS = ("chatbot", "imagegen", "live_captions")
NUM_REQUESTS = {"chatbot": 10, "imagegen": 10, "live_captions": 50,
                "deep_research": 1}
TOTAL_CHIPS = 256

_SMOKE_NUM_REQUESTS = {"chatbot": 2, "imagegen": 2, "live_captions": 5,
                       "deep_research": 1}
_smoke = False
_substrate = "simulator"


def set_substrate(substrate: str) -> None:
    """Select the execution substrate every figure Scenario runs on
    (``benchmarks/run.py --substrate engine``)."""
    from repro.bench import SUBSTRATES
    if substrate not in SUBSTRATES:
        raise ValueError(f"unknown substrate {substrate!r}; "
                         f"expected one of {SUBSTRATES}")
    global _substrate
    _substrate = substrate


def current_substrate() -> str:
    return _substrate


def enable_smoke() -> None:
    """Shrink every figure to a few requests: an import-and-run check, not a
    measurement (CI fast path)."""
    global _smoke
    _smoke = True
    NUM_REQUESTS.update(_SMOKE_NUM_REQUESTS)


if os.environ.get("CONSUMERBENCH_SMOKE", "").lower() not in ("", "0", "false"):
    enable_smoke()


def smoke_enabled() -> bool:
    return _smoke


def smoke_requests(n: int) -> int:
    """Clamp a figure-specific request count under smoke mode."""
    return min(n, 3) if _smoke else n


def standard_scenario(name: str, policy: str, *, mode: str = "concurrent",
                      chip: str = "tpu-v5e",
                      num_requests: dict[str, int] | None = None,
                      substrate: str | None = None) -> Scenario:
    """The paper's three-app concurrent workload as a Scenario declaration;
    runs on the module-selected substrate unless overridden."""
    counts = num_requests or NUM_REQUESTS
    return Scenario(
        name=name, mode=mode, policy=policy, total_chips=TOTAL_CHIPS,
        chip=chip, substrate=substrate or _substrate,
        apps=[ScenarioApp(app_type=t, num_requests=counts[t])
              for t in STANDARD_APPS])
