"""Shared helpers for the per-figure benchmarks. Every benchmark emits
``name,us_per_call,derived`` CSV rows (harness contract)."""
from __future__ import annotations

import time
from typing import Callable


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (real execution)."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


STANDARD_APPS = ("chatbot", "imagegen", "live_captions")
NUM_REQUESTS = {"chatbot": 10, "imagegen": 10, "live_captions": 50,
                "deep_research": 1}
