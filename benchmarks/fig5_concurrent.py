"""Paper Fig. 5: concurrent execution under greedy allocation vs static
partitioning — plus this repo's SLO-aware scheduler (paper §5.2's ask)."""
from __future__ import annotations

from benchmarks.common import NUM_REQUESTS, STANDARD_APPS, row
from repro.core.apps import make_app
from repro.core.orchestrator import Orchestrator


def run() -> list[str]:
    rows = []
    apps = [make_app(t) for t in STANDARD_APPS]
    nreq = {a.name: NUM_REQUESTS[a.name] for a in apps}
    for strategy in ("greedy", "static", "slo_aware"):
        orch = Orchestrator(total_chips=256, strategy=strategy)
        res = orch.run_concurrent(apps, nreq)
        for a in apps:
            rep = res.reports[a.name]
            st = rep.latency_stats()
            rows.append(row(
                f"fig5_{strategy}_{a.name}",
                st.get("mean", 0.0) * 1e6,
                f"slo={rep.attainment:.3f};"
                f"norm_lat={rep.normalized_latency():.3f};"
                f"util={res.utilization():.3f};"
                f"makespan_s={res.makespan_s:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
