"""Paper Fig. 5: concurrent execution under greedy allocation vs static
partitioning — plus this repo's SLO-aware scheduler (paper §5.2's ask),
the beyond-paper weighted-fair policy, and preemptive priority classes,
all through the policy registry. Runs on whichever substrate
``benchmarks/run.py --substrate`` selects (simulator or real engine)."""
from __future__ import annotations

from benchmarks.common import STANDARD_APPS, row, standard_scenario

POLICIES = ("greedy", "static", "slo_aware", "weighted_fair",
            "preemptive_priority")


def run() -> list[str]:
    rows = []
    for policy in POLICIES:
        res = standard_scenario(f"fig5-{policy}", policy).run()
        sim = res.sim
        for name in STANDARD_APPS:
            rep = sim.reports[name]
            st = rep.latency_stats()
            rows.append(row(
                f"fig5_{policy}_{name}",
                st.get("mean", 0.0) * 1e6,
                f"slo={rep.attainment:.3f};"
                f"norm_lat={rep.normalized_latency():.3f};"
                f"util={sim.utilization():.3f};"
                f"makespan_s={sim.makespan_s:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
