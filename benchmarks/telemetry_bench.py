"""Telemetry regression rows (``BENCH_telemetry.json`` in CI): one
telemetry-enabled concurrent scenario runs on BOTH substrates; the rows
pin the utilization/bandwidth means and event counts the
``repro.telemetry`` subsystem derives. Everything is virtual-clock
deterministic, so the rows diff through ``bench-diff`` like the kernel
and engine documents — a drift in SMACT/SMOCC/bandwidth accounting (or a
substrate diverging from its twin) trips the gate.

Row contract: value = makespan (µs) for the scenario rows; the
``*_smact_pct`` rows carry mean SMACT ×1e4 as the value so the 10%
relative gate applies to the utilization metric itself.
"""
from __future__ import annotations

from benchmarks.common import row, smoke_requests
from repro.bench import Scenario, ScenarioApp


def scenario(substrate: str) -> Scenario:
    return Scenario(
        name=f"telemetry-{substrate}", mode="concurrent", policy="slo_aware",
        total_chips=64, substrate=substrate, telemetry=True, seed=1,
        apps=[ScenarioApp("chatbot", num_requests=smoke_requests(4)),
              ScenarioApp("live_captions", num_requests=smoke_requests(8))])


def run() -> list[str]:
    rows = []
    for substrate in ("simulator", "engine"):
        res = scenario(substrate).run()
        summary = res.to_json()["results"]["concurrent"]
        blk = summary["telemetry"]
        n_events = sum(blk["events"].values())
        rows.append(row(
            f"telemetry_{substrate}", summary["makespan_s"] * 1e6,
            f"smact={blk['smact_mean']:.4f};smocc={blk['smocc_mean']:.4f};"
            f"bw_gbs={blk['bandwidth_gbs_mean']:.1f};events={n_events};"
            f"spans={sum(len(s) for s in blk['spans'].values())}"))
        rows.append(row(
            f"telemetry_{substrate}_smact_pct", blk["smact_mean"] * 1e4,
            f"bins={blk['bins']};power_w={blk['power_w_mean']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
