"""Stall-free mixed batching: decode TPOT p99 and decode-stall fraction
versus the per-step prefill budget share, mixed vs exclusive prefill.

The workload pairs a decode-heavy app (``chatbot``: short prompts, long
token streams, tight TPOT SLO) with a prefill-heavy one
(``deep_research``: 100s-scale prefill chains) on one partition — the
head-of-line-blocking shape the step-budget hook exists for. Three policy
families run the SAME (workload, seed):

* **fcfs** — exclusive prefill: a whole prompt monopolizes every step it
  runs in; decodes stall behind it (the paper's starvation mechanism);
* **chunked** — bounded prefill chunks, but still one prefill-only phase
  per step;
* **mixed @ share s** — ``MixedBatchPolicy``: every step spends
  ``s`` of its token budget on (multi-slot batched) prefill and the rest
  on decode, so decode rows advance EVERY step.

Per sweep point the row carries the chatbot's TPOT p99 (schema-1.7
per-app percentile), the run's ``decode_stall_fraction`` (schema-1.7
batching block), and the prefill app's makespan proxy (max e2e). The
50/50 row also carries the acceptance deltas vs fcfs: ``tpot_gain``
(TPOT p99 improvement, higher is better) and ``prefill_regress`` (the
prefill-makespan regression the budget is allowed to cost, <= 10%).
Engine rows rerun the sweep on the real InferenceEngine and carry the
cross-substrate ``stall_gap`` (absolute decode-stall-fraction gap,
required <= 0.05). All rows are virtual-clock deterministic and diff in
CI (``BENCH_stallfree.json``; stall fraction diffs lower-is-better).
"""
from __future__ import annotations

from benchmarks.common import row, smoke_enabled
from repro.bench import Scenario, ScenarioApp
from repro.bench.policy import MixedBatchPolicy

SHARES = (0.25, 0.5, 0.75)
SHARES_SMOKE = (0.5,)
CHAT_REQUESTS = 8
RESEARCH_REQUESTS = 2
CHAT_REQUESTS_SMOKE = 4
RESEARCH_REQUESTS_SMOKE = 1
SEED = 7


def scenario(policy, *, substrate: str = "simulator",
             tag: str = "") -> Scenario:
    smoke = smoke_enabled()
    return Scenario(
        name=f"stallfree-{tag}-{substrate}",
        mode="concurrent", policy=policy, total_chips=16,
        substrate=substrate, seed=SEED,
        apps=[ScenarioApp("chatbot", num_requests=(
                  CHAT_REQUESTS_SMOKE if smoke else CHAT_REQUESTS)),
              ScenarioApp("deep_research", num_requests=(
                  RESEARCH_REQUESTS_SMOKE if smoke else RESEARCH_REQUESTS))])


def _point_metrics(summary: dict) -> dict:
    """Derived metrics for one sweep point from the schema-1.7 blocks."""
    bat = summary.get("batching") or {}
    apps = summary.get("apps") or {}
    chat = apps.get("chatbot", {})
    research = apps.get("deep_research", {})
    return {
        "tpot_p99": chat.get("tpot_p99", 0.0),
        "ttft_p99": chat.get("ttft_p99", 0.0),
        "itl_p99": chat.get("itl_p99", 0.0),
        "stall_fraction": bat.get("decode_stall_fraction", 0.0),
        "mixed_steps": bat.get("mixed_steps", 0),
        "prefill_makespan": research.get("max", 0.0),
        "makespan": summary.get("makespan_s", 0.0),
    }


def _derived(m: dict, extra: str = "") -> str:
    s = (f"tpot_p99={m['tpot_p99']:.4f};"
         f"ttft_p99={m['ttft_p99']:.4f};"
         f"itl_p99={m['itl_p99']:.4f};"
         f"stall_fraction={m['stall_fraction']:.4f};"
         f"mixed_steps={m['mixed_steps']};"
         f"prefill_makespan={m['prefill_makespan']:.3f}")
    return s + (";" + extra if extra else "")


def run() -> list[str]:
    shares = SHARES_SMOKE if smoke_enabled() else SHARES
    rows = []
    sim_stall = {}

    base = _point_metrics(
        scenario("fcfs", tag="fcfs").run().sim.summary())
    sim_stall["fcfs"] = base["stall_fraction"]
    rows.append(row("stallfree_sim_fcfs", base["makespan"] * 1e6,
                    _derived(base)))
    m = _point_metrics(
        scenario("chunked", tag="chunked").run().sim.summary())
    sim_stall["chunked"] = m["stall_fraction"]
    rows.append(row("stallfree_sim_chunked", m["makespan"] * 1e6,
                    _derived(m)))
    for s in shares:
        pol = MixedBatchPolicy(prefill_share=s)
        m = _point_metrics(
            scenario(pol, tag=f"mixed{int(s * 100)}").run().sim.summary())
        sim_stall[s] = m["stall_fraction"]
        extra = ""
        if s == 0.5:
            # acceptance deltas vs exclusive prefill: decode TPOT p99 must
            # improve while the prefill makespan regresses <= 10%
            gain = ((base["tpot_p99"] - m["tpot_p99"]) / base["tpot_p99"]
                    if base["tpot_p99"] else 0.0)
            regress = ((m["prefill_makespan"] - base["prefill_makespan"])
                       / base["prefill_makespan"]
                       if base["prefill_makespan"] else 0.0)
            extra = f"tpot_gain={gain:.4f};prefill_regress={regress:.4f}"
        rows.append(row(f"stallfree_sim_mixed{int(s * 100)}",
                        m["makespan"] * 1e6, _derived(m, extra)))

    for tag, pol in (("fcfs", "fcfs"), ("chunked", "chunked"),
                     ("mixed50", MixedBatchPolicy(prefill_share=0.5))):
        key = 0.5 if tag == "mixed50" else tag
        m = _point_metrics(
            scenario(pol, substrate="engine", tag=tag).run().sim.summary())
        gap = abs(m["stall_fraction"] - sim_stall[key])
        rows.append(row(f"stallfree_engine_{tag}", m["makespan"] * 1e6,
                        _derived(m, f"stall_gap={gap:.4f}")))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
